//! `chaosjson` — machine-readable chaos stress report.
//!
//! Runs the recorded chaos matrix (engines × algorithms × schedules
//! from `tests/chaos_suite.rs`) and emits one schema-stable JSON
//! document per row: how many events the seeded schedule injected, how
//! many were loss, how many checkpoint rollbacks the run spent, whether
//! the retry budget was exhausted, whether the recovered replay matched
//! the clean baseline bit-for-bit, and whether loss without checkpoints
//! (or an exhausted budget) failed loudly. The committed
//! `STRESS_chaos_results.json` at the repository root is this tool's
//! output format (see its `provenance` field for how it was produced).
//!
//! ```text
//! cargo run --release --bin chaosjson                 # JSON on stdout
//! cargo run --release --bin chaosjson -- --out c.json
//! cargo run --release --bin chaosjson -- --quick      # CI smoke scale
//! ```
//!
//! Schema (version 2) — field order is fixed; additions bump the
//! version:
//!
//! ```text
//! { schema_version, suite, provenance, measured, quick,
//!   graph: { name, vertices, edges, partitions },
//!   rows: [ { engine, algo, schedule, seed, events, loss_events,
//!             recoveries, retries_exhausted, replay_equal, converged,
//!             matched_clean, loud_failure, error } ] }
//! ```
//!
//! v2 (universal recovery): every barrier engine now gets a
//! `stress+checkpoint` row (recoveries > 0, `replay_equal` asserts the
//! rolled-back replay reconverged on the clean fixpoint) and a
//! `kill-budget-0` row (`max_recoveries = 0` must surface the
//! structured budget-exhausted error, never loop); graphlab-sync gains
//! a `kill+checkpoint` recovery row and graphlab-async a
//! `checkpoint-config-error` row for its loud rejection.
//!
//! Every row is a pure function of its seed: two runs of this binary
//! produce byte-identical `rows` (the determinism the chaos suite
//! asserts), so the report doubles as a regression artifact.

use std::fmt::Write as _;
use std::process::ExitCode;

use graphhp::algorithms::{GasWcc, IncrementalPageRank, Sssp, Wcc};
use graphhp::bench_support::runner;
use graphhp::engine::{
    ChaosPolicy, ChaosSchedule, ChaosTrace, EngineKind, RecoveryPolicy, Runner,
};
use graphhp::graph::{generators, Graph};

const USAGE: &str = "usage: chaosjson [--out FILE] [--quick]\n\
  --out FILE  write the JSON document to FILE (default: stdout)\n\
  --quick     CI smoke scale: smaller grid, SSSP/WCC only";

struct ChaosRow {
    engine: String,
    algo: &'static str,
    schedule: &'static str,
    seed: u64,
    events: u64,
    loss_events: u64,
    recoveries: u64,
    retries_exhausted: bool,
    replay_equal: bool,
    converged: bool,
    matched_clean: bool,
    loud_failure: bool,
    error: String,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn trace_counts(t: &Option<ChaosTrace>) -> (u64, u64) {
    match t {
        Some(t) => (t.events.len() as u64, t.loss_events()),
        None => (0, 0),
    }
}

/// The kill-only schedule every engine must fail loudly on when no
/// checkpoints are configured (graphlab-async excepted, by contract).
fn kill_policy(seed: u64) -> ChaosPolicy {
    ChaosPolicy { seed, schedule: ChaosSchedule { kill_at: vec![1], ..Default::default() } }
}

/// benign / stress+checkpoint / kill-no-checkpoint rows for one push
/// engine and one algorithm. `matched` compares against the clean
/// baseline with the algorithm's own tolerance.
fn push_rows<P, F>(
    rows: &mut Vec<ChaosRow>,
    g: &Graph,
    kind: EngineKind,
    algo: &'static str,
    base_seed: u64,
    prog: &P,
    matched: F,
) where
    P: graphhp::engine::VertexProgram,
    F: Fn(&[P::V], &[P::V]) -> bool,
{
    let clean = runner(g, 4).engine(kind).run(prog);

    let benign = runner(g, 4).engine(kind).chaos(ChaosPolicy::benign(base_seed)).run(prog);
    let (events, loss) = trace_counts(&benign.chaos);
    rows.push(ChaosRow {
        engine: kind.to_string(),
        algo,
        schedule: "benign",
        seed: base_seed,
        events,
        loss_events: loss,
        recoveries: benign.metrics.recoveries,
        retries_exhausted: false,
        replay_equal: false,
        converged: true,
        matched_clean: matched(&clean.values, &benign.values),
        loud_failure: false,
        error: String::new(),
    });

    // every barrier engine checkpoints and rolls back through the
    // shared recovery layer (engine/recovery.rs)
    let stress = runner(g, 4)
        .engine(kind)
        .checkpoint_interval(Some(2))
        .chaos(ChaosPolicy::stress(base_seed + 1))
        .run(prog);
    let (events, loss) = trace_counts(&stress.chaos);
    let stress_matched = matched(&clean.values, &stress.values);
    rows.push(ChaosRow {
        engine: kind.to_string(),
        algo,
        schedule: "stress+checkpoint",
        seed: base_seed + 1,
        events,
        loss_events: loss,
        recoveries: stress.metrics.recoveries,
        retries_exhausted: false,
        replay_equal: stress.metrics.recoveries > 0 && stress_matched,
        converged: true,
        matched_clean: stress_matched,
        loud_failure: false,
        error: String::new(),
    });

    let killed = runner(g, 4).engine(kind).chaos(kill_policy(base_seed + 2)).try_run(prog);
    let (loud, error) = match killed {
        Ok(_) => (false, "kill without checkpoints converged silently".to_string()),
        Err(e) => (e.starts_with("chaos:"), e),
    };
    rows.push(ChaosRow {
        engine: kind.to_string(),
        algo,
        schedule: "kill-no-checkpoint",
        seed: base_seed + 2,
        events: 0,
        loss_events: 0,
        recoveries: 0,
        retries_exhausted: false,
        replay_equal: false,
        converged: false,
        matched_clean: false,
        loud_failure: loud,
        error,
    });

    // a zero retry budget turns the very first rollback into the
    // structured budget-exhausted error — the bounded-retry contract
    let broke = runner(g, 4)
        .engine(kind)
        .checkpoint_interval(Some(2))
        .recovery(RecoveryPolicy { max_recoveries: 0, ..Default::default() })
        .chaos(kill_policy(base_seed + 3))
        .try_run(prog);
    let (loud, exhausted, error) = match broke {
        Ok(_) => (false, false, "zero-budget kill converged silently".to_string()),
        Err(e) => (e.starts_with("chaos:"), e.contains("recovery budget exhausted"), e),
    };
    rows.push(ChaosRow {
        engine: kind.to_string(),
        algo,
        schedule: "kill-budget-0",
        seed: base_seed + 3,
        events: 0,
        loss_events: 0,
        recoveries: 0,
        retries_exhausted: exhausted,
        replay_equal: false,
        converged: false,
        matched_clean: false,
        loud_failure: loud,
        error,
    });
}

/// The pull-engine rows: graphlab-sync fails loudly on a kill without
/// checkpoints but recovers bit-exactly with them; graphlab-async is
/// documented out of scope and rejects a checkpoint policy loudly.
fn gas_rows(rows: &mut Vec<ChaosRow>, g: &Graph, base_seed: u64) {
    let sync = EngineKind::GraphLabSync;
    let clean = Runner::new(g).partitions(4).engine(sync).run_gas(&GasWcc);
    let benign = Runner::new(g)
        .partitions(4)
        .engine(sync)
        .chaos(ChaosPolicy::benign(base_seed))
        .run_gas(&GasWcc);
    let (events, loss) = trace_counts(&benign.chaos);
    rows.push(ChaosRow {
        engine: sync.to_string(),
        algo: "wcc",
        schedule: "benign",
        seed: base_seed,
        events,
        loss_events: loss,
        recoveries: benign.metrics.recoveries,
        retries_exhausted: false,
        replay_equal: false,
        converged: true,
        matched_clean: clean.values == benign.values,
        loud_failure: false,
        error: String::new(),
    });
    let killed = Runner::new(g)
        .partitions(4)
        .engine(sync)
        .chaos(kill_policy(base_seed + 1))
        .try_run_gas(&GasWcc);
    let (loud, error) = match killed {
        Ok(_) => (false, "kill without checkpoints converged silently".to_string()),
        Err(e) => (e.starts_with("chaos:"), e),
    };
    rows.push(ChaosRow {
        engine: sync.to_string(),
        algo: "wcc",
        schedule: "kill-no-checkpoint",
        seed: base_seed + 1,
        events: 0,
        loss_events: 0,
        recoveries: 0,
        retries_exhausted: false,
        replay_equal: false,
        converged: false,
        matched_clean: false,
        loud_failure: loud,
        error,
    });

    // with a checkpoint interval the sync engine rolls back in-memory
    // GasSnapshots and reconverges on the clean fixpoint
    let recovered = Runner::new(g)
        .partitions(4)
        .engine(sync)
        .checkpoint_interval(Some(2))
        .chaos(kill_policy(base_seed + 2))
        .run_gas(&GasWcc);
    let (events, loss) = trace_counts(&recovered.chaos);
    let rec_matched = clean.values == recovered.values;
    rows.push(ChaosRow {
        engine: sync.to_string(),
        algo: "wcc",
        schedule: "kill+checkpoint",
        seed: base_seed + 2,
        events,
        loss_events: loss,
        recoveries: recovered.metrics.recoveries,
        retries_exhausted: false,
        replay_equal: recovered.metrics.recoveries > 0 && rec_matched,
        converged: true,
        matched_clean: rec_matched,
        loud_failure: false,
        error: String::new(),
    });

    let kind = EngineKind::GraphLabAsync;
    let r = Runner::new(g)
        .partitions(4)
        .engine(kind)
        .chaos(kill_policy(base_seed + 3))
        .run_gas(&GasWcc);
    rows.push(ChaosRow {
        engine: kind.to_string(),
        algo: "wcc",
        schedule: "out-of-scope",
        seed: base_seed + 3,
        events: 0,
        loss_events: 0,
        recoveries: 0,
        retries_exhausted: false,
        replay_equal: false,
        converged: true,
        matched_clean: r.chaos.is_none() && clean.values == r.values,
        loud_failure: false,
        error: String::new(),
    });

    // the async engine has no barriers: a configured checkpoint policy
    // must be rejected loudly, never dropped on the floor
    let rejected = Runner::new(g)
        .partitions(4)
        .engine(kind)
        .checkpoint_interval(Some(2))
        .try_run_gas(&GasWcc);
    let (loud, error) = match rejected {
        Ok(_) => (false, "async accepted a checkpoint policy silently".to_string()),
        Err(e) => (e.starts_with("config:"), e),
    };
    rows.push(ChaosRow {
        engine: kind.to_string(),
        algo: "wcc",
        schedule: "checkpoint-config-error",
        seed: base_seed + 4,
        events: 0,
        loss_events: 0,
        recoveries: 0,
        retries_exhausted: false,
        replay_equal: false,
        converged: false,
        matched_clean: false,
        loud_failure: loud,
        error,
    });
}

fn main() -> ExitCode {
    let mut out_path: Option<String> = None;
    let mut quick = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--out" => match args.next() {
                Some(p) => out_path = Some(p),
                None => {
                    eprintln!("{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--quick" => quick = true,
            _ => {
                eprintln!("{USAGE}");
                return ExitCode::from(2);
            }
        }
    }

    // long-diameter grid: every run outlives the stress kill (barrier 5)
    let (gname, g) =
        if quick { ("road-12x12", generators::road(12, 12, 9)) } else { ("road-20x20", generators::road(20, 20, 9)) };
    let engines: Vec<EngineKind> = if quick {
        vec![EngineKind::Hama, EngineKind::GraphHP]
    } else {
        EngineKind::VERTEX_CENTRIC.to_vec()
    };

    let mut rows: Vec<ChaosRow> = Vec::new();
    for (ei, &kind) in engines.iter().enumerate() {
        let base = 100 * (ei as u64 + 1);
        eprintln!("chaosjson: {kind}");
        push_rows(&mut rows, &g, kind, "sssp", base, &Sssp { source: 0 }, |a, b| {
            a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
        });
        push_rows(&mut rows, &g, kind, "wcc", base + 10, &Wcc, |a, b| a == b);
        if !quick {
            push_rows(
                &mut rows,
                &g,
                kind,
                "pagerank",
                base + 20,
                &IncrementalPageRank { tolerance: 1e-6 },
                |a, b| a.iter().zip(b).all(|(x, y)| (x - y).abs() < 1e-6),
            );
        }
    }
    if !quick {
        eprintln!("chaosjson: graphlab");
        gas_rows(&mut rows, &g, 900);
    }

    let mut doc = String::new();
    doc.push_str("{\n");
    let _ = writeln!(doc, "  \"schema_version\": 2,");
    let _ = writeln!(doc, "  \"suite\": \"chaos_stress\",");
    let _ = writeln!(
        doc,
        "  \"provenance\": \"chaosjson v{} ({})\",",
        env!("CARGO_PKG_VERSION"),
        if quick { "quick" } else { "full" },
    );
    let _ = writeln!(doc, "  \"measured\": true,");
    let _ = writeln!(doc, "  \"quick\": {quick},");
    let _ = writeln!(
        doc,
        "  \"graph\": {{ \"name\": \"{}\", \"vertices\": {}, \"edges\": {}, \"partitions\": 4 }},",
        gname,
        g.num_vertices(),
        g.num_edges(),
    );
    doc.push_str("  \"rows\": [\n");
    for (ri, r) in rows.iter().enumerate() {
        let _ = writeln!(
            doc,
            "    {{ \"engine\": \"{}\", \"algo\": \"{}\", \"schedule\": \"{}\", \
             \"seed\": {}, \"events\": {}, \"loss_events\": {}, \"recoveries\": {}, \
             \"retries_exhausted\": {}, \"replay_equal\": {}, \
             \"converged\": {}, \"matched_clean\": {}, \"loud_failure\": {}, \
             \"error\": \"{}\" }}{}",
            json_escape(&r.engine),
            r.algo,
            r.schedule,
            r.seed,
            r.events,
            r.loss_events,
            r.recoveries,
            r.retries_exhausted,
            r.replay_equal,
            r.converged,
            r.matched_clean,
            r.loud_failure,
            json_escape(&r.error),
            if ri + 1 < rows.len() { "," } else { "" },
        );
    }
    doc.push_str("  ]\n}\n");

    // the contract the chaos suite asserts, re-checked on the report
    let bad: Vec<&ChaosRow> = rows
        .iter()
        .filter(|r| match r.schedule {
            "kill-no-checkpoint" | "checkpoint-config-error" => !r.loud_failure,
            "kill-budget-0" => !(r.loud_failure && r.retries_exhausted),
            "stress+checkpoint" | "kill+checkpoint" => !r.replay_equal,
            _ => !r.matched_clean,
        })
        .collect();
    for r in &bad {
        eprintln!(
            "chaosjson: CONTRACT VIOLATION {} {} {}: {}",
            r.engine, r.algo, r.schedule, r.error
        );
    }

    match out_path {
        Some(p) => {
            if let Err(e) = std::fs::write(&p, &doc) {
                eprintln!("chaosjson: write {p}: {e}");
                return ExitCode::from(2);
            }
            eprintln!("chaosjson: wrote {p}");
        }
        None => print!("{doc}"),
    }
    if bad.is_empty() { ExitCode::SUCCESS } else { ExitCode::from(1) }
}
