//! `benchjson` — machine-readable sweep-throughput benchmark.
//!
//! Runs the fig6/fig9 sweep workloads (fixed-superstep ClassicPageRank)
//! across engines × memory layouts × parallelism modes and emits one
//! schema-stable JSON document: sweeps/sec (and per core), bytes/edge of
//! the built edge columns, and allocations/superstep. The committed
//! `BENCH_sweep_scaling.json` at the repository root is this tool's
//! output format (see its `provenance` field for how it was produced).
//!
//! ```text
//! cargo run --release --bin benchjson                 # JSON on stdout
//! cargo run --release --bin benchjson -- --out b.json
//! cargo run --release --bin benchjson -- --quick      # CI smoke scale
//! GRAPHHP_BENCH_SCALE=large cargo run --release --bin benchjson
//! ```
//!
//! Schema (version 1) — field order is fixed; additions bump the
//! version:
//!
//! ```text
//! { schema_version, suite, provenance, measured, bench_scale,
//!   host_threads, supersteps,
//!   graphs: [ { name, vertices, edges, partitions,
//!     layouts: [ { layout, edge_column_bytes, bytes_per_edge } ],
//!     runs: [ { engine, layout, mode, cores, wall_seconds,
//!               supersteps, sweeps, sweeps_per_sec,
//!               sweeps_per_sec_per_core, allocs_per_superstep } ] } ] }
//! ```
//!
//! Every workload is a pure function of its seed, so two runs on the
//! same host differ only in the timing fields.

use std::alloc::{GlobalAlloc, Layout, System};
use std::fmt::Write as _;
use std::process::ExitCode;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use graphhp::algorithms::ClassicPageRank;
use graphhp::bench_support as bs;
use graphhp::engine::{EngineKind, Parallelism, Partitioner, Runner};
use graphhp::graph::{generators, Graph, GraphLayout};
use graphhp::partition::{metis_partition, MetisConfig};

/// Counts allocator calls (same probe as `fig9_sweep_hotpath`).
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

const USAGE: &str = "usage: benchjson [--out FILE] [--quick]\n\
  --out FILE  write the JSON document to FILE (default: stdout)\n\
  --quick     CI smoke scale: fewer supersteps and parallelism modes\n\
  env: GRAPHHP_BENCH_SCALE=small|medium|large selects the graph sizes";

fn mode_name(par: Parallelism) -> String {
    match par {
        Parallelism::Sequential => "sequential".to_string(),
        Parallelism::Threads(n) => format!("threads={n}"),
        Parallelism::WorkStealing(n) => format!("steal={n}"),
    }
}

fn mode_cores(par: Parallelism) -> usize {
    match par {
        Parallelism::Sequential => 1,
        Parallelism::Threads(n) | Parallelism::WorkStealing(n) => n.max(1),
    }
}

struct RunRow {
    engine: String,
    layout: &'static str,
    mode: String,
    cores: usize,
    wall_seconds: f64,
    supersteps: u64,
    sweeps: u64,
    allocs: u64,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn main() -> ExitCode {
    let mut out_path: Option<String> = None;
    let mut quick = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--out" => match args.next() {
                Some(p) => out_path = Some(p),
                None => {
                    eprintln!("{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--quick" => quick = true,
            _ => {
                eprintln!("{USAGE}");
                return ExitCode::from(2);
            }
        }
    }

    let scale = bs::bench_scale();
    let parts = 12usize;
    let supersteps: u64 = if quick { 5 } else { 20 };
    // two graph scales minimum at every BenchScale (acceptance contract)
    let graphs: Vec<(&str, Graph)> = scale.pick(
        vec![
            ("powerlaw-20k-d5", generators::powerlaw(20_000, 5, 7)),
            ("web-65k-d8", generators::web(1 << 16, 8, 7)),
        ],
        vec![
            ("web-262k-d8", generators::web(1 << 18, 8, 7)),
            ("rmat-s16-e8", generators::rmat(16, 8, 7)),
        ],
        vec![
            ("rmat-s20-e16", generators::rmat(20, 16, 7)),
            ("web-2m-d8", generators::web(1 << 21, 8, 7)),
        ],
    );
    let modes: Vec<Parallelism> = if quick {
        vec![Parallelism::Sequential, Parallelism::Threads(2), Parallelism::WorkStealing(2)]
    } else {
        vec![
            Parallelism::Sequential,
            Parallelism::Threads(2),
            Parallelism::Threads(4),
            Parallelism::WorkStealing(2),
            Parallelism::WorkStealing(4),
        ]
    };
    let layouts: [(&str, GraphLayout); 2] =
        [("soa", GraphLayout::default()), ("packed", GraphLayout::packed())];
    let engines = [EngineKind::Hama, EngineKind::GraphHP];
    let host_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    let mut doc = String::new();
    doc.push_str("{\n");
    let _ = writeln!(doc, "  \"schema_version\": 1,");
    let _ = writeln!(doc, "  \"suite\": \"sweep_scaling\",");
    let _ = writeln!(
        doc,
        "  \"provenance\": \"benchjson v{} ({}, {} supersteps)\",",
        env!("CARGO_PKG_VERSION"),
        if quick { "quick" } else { "full" },
        supersteps,
    );
    let _ = writeln!(doc, "  \"measured\": true,");
    let _ = writeln!(doc, "  \"bench_scale\": \"{}\",", scale.name());
    let _ = writeln!(doc, "  \"host_threads\": {host_threads},");
    let _ = writeln!(doc, "  \"supersteps\": {supersteps},");
    doc.push_str("  \"graphs\": [\n");

    let prog = ClassicPageRank { supersteps };
    for (gi, (name, g)) in graphs.iter().enumerate() {
        eprintln!("benchjson: {name} ({} vertices, {} edges)", g.num_vertices(), g.num_edges());
        let assignment = metis_partition(g, parts, &MetisConfig::default());
        doc.push_str("    {\n");
        let _ = writeln!(doc, "      \"name\": \"{}\",", json_escape(name));
        let _ = writeln!(doc, "      \"vertices\": {},", g.num_vertices());
        let _ = writeln!(doc, "      \"edges\": {},", g.num_edges());
        let _ = writeln!(doc, "      \"partitions\": {parts},");
        doc.push_str("      \"layouts\": [\n");
        let mut rows: Vec<RunRow> = Vec::new();
        for (li, (lname, layout)) in layouts.iter().enumerate() {
            let mut runner = Runner::new(g)
                .partitions(parts)
                .partitioner(Partitioner::Explicit(assignment.clone()))
                .layout(*layout);
            let dg = runner.dist();
            let bytes = dg.edge_column_bytes();
            let _ = writeln!(
                doc,
                "        {{ \"layout\": \"{lname}\", \"edge_column_bytes\": {bytes}, \
                 \"bytes_per_edge\": {:.3} }}{}",
                bytes as f64 / g.num_edges().max(1) as f64,
                if li + 1 < layouts.len() { "," } else { "" },
            );
            for kind in engines {
                for &par in &modes {
                    runner = runner.engine(kind).parallelism(par);
                    let a0 = ALLOC_CALLS.load(Ordering::Relaxed);
                    // detlint: allow(wall-clock) — benchmark harness:
                    // measures run wall-clock for the JSON report only,
                    // never feeds results or scheduling.
                    let t0 = Instant::now();
                    let r = runner.run(&prog);
                    let wall = t0.elapsed();
                    let a1 = ALLOC_CALLS.load(Ordering::Relaxed);
                    rows.push(RunRow {
                        engine: kind.to_string(),
                        layout: lname,
                        mode: mode_name(par),
                        cores: mode_cores(par),
                        wall_seconds: wall.as_secs_f64(),
                        supersteps: r.metrics.supersteps_total,
                        sweeps: r.metrics.vertex_computations,
                        allocs: a1 - a0,
                    });
                }
            }
        }
        doc.push_str("      ],\n");
        doc.push_str("      \"runs\": [\n");
        for (ri, row) in rows.iter().enumerate() {
            let rate = row.sweeps as f64 / row.wall_seconds.max(1e-9);
            let _ = writeln!(
                doc,
                "        {{ \"engine\": \"{}\", \"layout\": \"{}\", \"mode\": \"{}\", \
                 \"cores\": {}, \"wall_seconds\": {:.6}, \"supersteps\": {}, \
                 \"sweeps\": {}, \"sweeps_per_sec\": {:.0}, \
                 \"sweeps_per_sec_per_core\": {:.0}, \"allocs_per_superstep\": {:.1} }}{}",
                json_escape(&row.engine),
                row.layout,
                row.mode,
                row.cores,
                row.wall_seconds,
                row.supersteps,
                row.sweeps,
                rate,
                rate / row.cores as f64,
                row.allocs as f64 / row.supersteps.max(1) as f64,
                if ri + 1 < rows.len() { "," } else { "" },
            );
        }
        doc.push_str("      ]\n");
        let _ = writeln!(doc, "    }}{}", if gi + 1 < graphs.len() { "," } else { "" });
    }
    doc.push_str("  ]\n}\n");

    match out_path {
        Some(p) => {
            if let Err(e) = std::fs::write(&p, &doc) {
                eprintln!("benchjson: write {p}: {e}");
                return ExitCode::from(2);
            }
            eprintln!("benchjson: wrote {p}");
        }
        None => print!("{doc}"),
    }
    ExitCode::SUCCESS
}
