//! Shared helpers for the paper-reproduction bench binaries
//! (`rust/benches/*.rs`, `harness = false` — the offline vendor set has
//! no criterion). Each bench regenerates one table/figure of the paper's
//! evaluation section and prints it in the paper's row format.
//!
//! The generate → partition → distribute → run plumbing every bench
//! needs lives here as thin wrappers over the [`Runner`] session, so a
//! bench is just: build a workload, `bs::runner(&g, k)`, run/compare.

use crate::engine::{EngineKind, Metrics, RunResult, Runner, VertexProgram};
use crate::graph::{DistGraph, Graph};
use crate::partition::{metis_partition, MetisConfig};

/// Print a bench header with the paper reference.
pub fn header(title: &str, paper_ref: &str) {
    println!("\n{}", "=".repeat(78));
    println!("{title}");
    println!("reproduces: {paper_ref}");
    println!("{}", "=".repeat(78));
}

/// Paper-style metric row: engine, I, M, T (+ overhead split).
pub fn row(engine: &str, m: &Metrics) {
    println!(
        "  {engine:<16} I={:<8} M={:<12} T={:>9.3}s  (compute {:>4.1}% | comm {:>4.1}% | sync {:>4.1}%)",
        m.global_iterations,
        m.network_messages,
        m.elapsed.as_secs_f64(),
        100.0 * (1.0 - m.overhead_fraction()),
        100.0 * m.comm_fraction(),
        100.0 * m.sync_fraction(),
    );
}

/// CSV-ish series line for figures (easy to re-plot).
pub fn series(label: &str, xs: &[usize], ys: &[f64]) {
    let pts: Vec<String> =
        xs.iter().zip(ys).map(|(x, y)| format!("({x}, {y:.4})")).collect();
    println!("  {label:<22} {}", pts.join(" "));
}

/// A [`Runner`] session over `g` with `k` metis partitions — the
/// standard bench setup (the paper partitions with ParMetis).
pub fn runner(g: &Graph, k: usize) -> Runner<'_> {
    Runner::new(g).partitions(k)
}

/// Run `program` on each engine kind over one shared partitioned view,
/// printing a paper-style row per engine; returns the results for shape
/// checks.
pub fn compare_rows<P: VertexProgram>(
    r: &mut Runner<'_>,
    kinds: &[EngineKind],
    program: &P,
) -> Vec<(EngineKind, RunResult<P::V>)> {
    let results = r.compare(kinds, program);
    for (kind, res) in &results {
        row(&kind.to_string(), &res.metrics);
    }
    results
}

/// Metis-partition `g` into `k` parts and build the distributed view
/// (for call sites that need an owned [`DistGraph`]).
pub fn dist(g: &Graph, k: usize) -> DistGraph {
    let a = metis_partition(g, k, &MetisConfig::default());
    DistGraph::new(g, &a, k)
}

/// Scale note printed by every bench.
pub fn scale_note(paper_workload: &str, ours: &str) {
    println!("workload: {ours}");
    println!("(paper used {paper_workload}; scaled for a single-core CI box —");
    println!(" compare SHAPES: who wins, by what factor, where crossovers fall)\n");
}

/// Quick check helper: expected ordering of two metrics with a margin.
pub fn expect_less(label: &str, a: u64, b: u64) {
    if a < b {
        println!("  ✓ {label}: {a} < {b}");
    } else {
        println!("  ✗ {label} VIOLATED: {a} >= {b}");
    }
}
