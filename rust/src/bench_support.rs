//! Shared helpers for the paper-reproduction bench binaries
//! (`rust/benches/*.rs`, `harness = false` — the offline vendor set has
//! no criterion). Each bench regenerates one table/figure of the paper's
//! evaluation section and prints it in the paper's row format.
//!
//! The generate → partition → distribute → run plumbing every bench
//! needs lives here as thin wrappers over the [`Runner`] session, so a
//! bench is just: build a workload, `bs::runner(&g, k)`, run/compare.

use crate::engine::{EngineKind, Metrics, RunResult, Runner, VertexProgram};
use crate::graph::{DistGraph, Graph};
use crate::partition::{metis_partition, MetisConfig};

/// Print a bench header with the paper reference.
pub fn header(title: &str, paper_ref: &str) {
    println!("\n{}", "=".repeat(78));
    println!("{title}");
    println!("reproduces: {paper_ref}");
    println!("{}", "=".repeat(78));
}

/// Paper-style metric row: engine, I, M, T (+ overhead split).
pub fn row(engine: &str, m: &Metrics) {
    println!(
        "  {engine:<16} I={:<8} M={:<12} T={:>9.3}s  (compute {:>4.1}% | comm {:>4.1}% | sync {:>4.1}%)",
        m.global_iterations,
        m.network_messages,
        m.elapsed.as_secs_f64(),
        100.0 * (1.0 - m.overhead_fraction()),
        100.0 * m.comm_fraction(),
        100.0 * m.sync_fraction(),
    );
}

/// CSV-ish series line for figures (easy to re-plot).
pub fn series(label: &str, xs: &[usize], ys: &[f64]) {
    let pts: Vec<String> =
        xs.iter().zip(ys).map(|(x, y)| format!("({x}, {y:.4})")).collect();
    println!("  {label:<22} {}", pts.join(" "));
}

/// A [`Runner`] session over `g` with `k` metis partitions — the
/// standard bench setup (the paper partitions with ParMetis).
pub fn runner(g: &Graph, k: usize) -> Runner<'_> {
    Runner::new(g).partitions(k)
}

/// Run `program` on each engine kind over one shared partitioned view,
/// printing a paper-style row per engine; returns the results for shape
/// checks.
pub fn compare_rows<P: VertexProgram>(
    r: &mut Runner<'_>,
    kinds: &[EngineKind],
    program: &P,
) -> Vec<(EngineKind, RunResult<P::V>)> {
    let results = r.compare(kinds, program);
    for (kind, res) in &results {
        row(&kind.to_string(), &res.metrics);
    }
    results
}

/// Metis-partition `g` into `k` parts and build the distributed view
/// (for call sites that need an owned [`DistGraph`]).
pub fn dist(g: &Graph, k: usize) -> DistGraph {
    let a = metis_partition(g, k, &MetisConfig::default());
    DistGraph::new(g, &a, k)
}

/// Scale note printed by every bench.
pub fn scale_note(paper_workload: &str, ours: &str) {
    println!("workload: {ours}");
    println!("(paper used {paper_workload}; scaled for a single-core CI box —");
    println!(" compare SHAPES: who wins, by what factor, where crossovers fall)\n");
}

/// Workload size selected by the `GRAPHHP_BENCH_SCALE` environment
/// variable — `small` (default, CI-friendly seconds-scale runs),
/// `medium` (~1-2M edges), or `large` (10M+ edges, the bandwidth-bound
/// regime the degree-sorted/compressed layouts and
/// `Parallelism::WorkStealing` target). Benches keep their historical
/// workloads at `small` so existing numbers stay comparable.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BenchScale {
    Small,
    Medium,
    Large,
}

impl BenchScale {
    /// Pick the value for the current scale.
    pub fn pick<T>(self, small: T, medium: T, large: T) -> T {
        match self {
            BenchScale::Small => small,
            BenchScale::Medium => medium,
            BenchScale::Large => large,
        }
    }

    /// Lower-case name (matches the env-var spelling).
    pub fn name(self) -> &'static str {
        self.pick("small", "medium", "large")
    }
}

/// Read `GRAPHHP_BENCH_SCALE` (unset → `Small`; unknown values panic so
/// typos fail loudly instead of silently benchmarking the wrong size).
pub fn bench_scale() -> BenchScale {
    match std::env::var("GRAPHHP_BENCH_SCALE").as_deref() {
        Err(_) | Ok("") | Ok("small") => BenchScale::Small,
        Ok("medium") => BenchScale::Medium,
        Ok("large") => BenchScale::Large,
        Ok(other) => panic!("GRAPHHP_BENCH_SCALE={other:?}: use small|medium|large"),
    }
}

/// Quick check helper: expected ordering of two metrics with a margin.
pub fn expect_less(label: &str, a: u64, b: u64) {
    if a < b {
        println!("  ✓ {label}: {a} < {b}");
    } else {
        println!("  ✗ {label} VIOLATED: {a} >= {b}");
    }
}
