//! From-scratch multilevel k-way graph partitioner (the ParMetis
//! stand-in, cf. Karypis & Kumar).
//!
//! Pipeline:
//! 1. **Coarsen** — repeated heavy-edge matching collapses matched vertex
//!    pairs into super-vertices (edge weights accumulate) until the graph
//!    is small or matching stalls.
//! 2. **Initial partition** — greedy region growing on the coarsest
//!    graph: k BFS fronts seeded far apart, always expanding the lightest
//!    part.
//! 3. **Uncoarsen + refine** — project the assignment back level by
//!    level, running boundary Fiduccia–Mattheyses passes: move boundary
//!    vertices to the neighbor part with the best gain subject to a
//!    balance cap.
//!
//! Works on the undirected weighted view of the input digraph (edge
//! directions don't matter for locality).

use crate::graph::{Graph, VertexId};
use crate::util::Rng;

/// Tuning knobs for [`metis_partition`].
#[derive(Clone, Debug)]
pub struct MetisConfig {
    /// Stop coarsening when the graph has at most `coarse_factor * k`
    /// vertices.
    pub coarse_factor: usize,
    /// Maximum allowed part weight as a multiple of average (1.05 = 5%
    /// imbalance).
    pub balance_cap: f64,
    /// FM refinement passes per uncoarsening level.
    pub refine_passes: usize,
    /// RNG seed (tie-breaking, seed placement).
    pub seed: u64,
}

impl Default for MetisConfig {
    fn default() -> Self {
        MetisConfig { coarse_factor: 30, balance_cap: 1.05, refine_passes: 4, seed: 1 }
    }
}

/// Undirected weighted graph used internally at every level.
struct Level {
    /// CSR adjacency (symmetric).
    offsets: Vec<usize>,
    neigh: Vec<u32>,
    w: Vec<f64>,
    /// Vertex weights (number of original vertices collapsed in).
    vw: Vec<f64>,
    /// Mapping from this level's vertices to the coarser level's.
    coarse_map: Vec<u32>,
}

impl Level {
    fn nv(&self) -> usize {
        self.offsets.len() - 1
    }
    fn edges(&self, v: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let (s, e) = (self.offsets[v], self.offsets[v + 1]);
        self.neigh[s..e].iter().copied().zip(self.w[s..e].iter().copied())
    }
}

/// Build the symmetric level-0 view of `g` (parallel edges merged,
/// self-loops dropped, weight = multiplicity — cut count is what matters
/// for BSP communication, not the f32 weights).
fn undirected_view(g: &Graph) -> Level {
    let nv = g.num_vertices();
    let mut pairs: Vec<(u32, u32)> = Vec::with_capacity(g.num_edges() * 2);
    for v in 0..nv as VertexId {
        for &t in g.out_edges(v).0 {
            if t != v {
                pairs.push((v.min(t), v.max(t)));
            }
        }
    }
    pairs.sort_unstable();
    // multiplicity-merged undirected edges
    let mut merged: Vec<(u32, u32, f64)> = Vec::new();
    for (a, b) in pairs {
        match merged.last_mut() {
            Some(&mut (la, lb, ref mut w)) if la == a && lb == b => *w += 1.0,
            _ => merged.push((a, b, 1.0)),
        }
    }
    let mut deg = vec![0usize; nv];
    for &(a, b, _) in &merged {
        deg[a as usize] += 1;
        deg[b as usize] += 1;
    }
    let mut offsets = vec![0usize; nv + 1];
    for i in 0..nv {
        offsets[i + 1] = offsets[i] + deg[i];
    }
    let mut pos = offsets.clone();
    let mut neigh = vec![0u32; merged.len() * 2];
    let mut w = vec![0f64; merged.len() * 2];
    for &(a, b, wt) in &merged {
        neigh[pos[a as usize]] = b;
        w[pos[a as usize]] = wt;
        pos[a as usize] += 1;
        neigh[pos[b as usize]] = a;
        w[pos[b as usize]] = wt;
        pos[b as usize] += 1;
    }
    Level { offsets, neigh, w, vw: vec![1.0; nv], coarse_map: Vec::new() }
}

/// Heavy-edge matching: visit vertices in random order, match each
/// unmatched vertex to its heaviest unmatched neighbor. Returns the
/// coarse graph; `level.coarse_map` is filled in.
fn coarsen(level: &mut Level, rng: &mut Rng) -> Level {
    let nv = level.nv();
    let mut order: Vec<u32> = (0..nv as u32).collect();
    rng.shuffle(&mut order);
    let mut mate: Vec<u32> = (0..nv as u32).collect(); // self = unmatched
    let mut matched = vec![false; nv];
    for &v in &order {
        if matched[v as usize] {
            continue;
        }
        let mut best: Option<(u32, f64)> = None;
        for (u, w) in level.edges(v as usize) {
            if !matched[u as usize] && u != v {
                if best.map_or(true, |(_, bw)| w > bw) {
                    best = Some((u, w));
                }
            }
        }
        if let Some((u, _)) = best {
            mate[v as usize] = u;
            mate[u as usize] = v;
            matched[v as usize] = true;
            matched[u as usize] = true;
        }
    }
    // assign coarse ids
    let mut coarse_map = vec![u32::MAX; nv];
    let mut next = 0u32;
    for v in 0..nv as u32 {
        if coarse_map[v as usize] != u32::MAX {
            continue;
        }
        coarse_map[v as usize] = next;
        let m = mate[v as usize];
        if m != v {
            coarse_map[m as usize] = next;
        }
        next += 1;
    }
    let cnv = next as usize;
    // build coarse adjacency by hashing pair buckets
    let mut cvw = vec![0f64; cnv];
    for v in 0..nv {
        cvw[coarse_map[v] as usize] += level.vw[v];
    }
    let mut cpairs: Vec<(u32, u32, f64)> = Vec::new();
    for v in 0..nv {
        let cv = coarse_map[v];
        for (u, w) in level.edges(v) {
            let cu = coarse_map[u as usize];
            if cu != cv {
                cpairs.push((cv.min(cu), cv.max(cu), w));
            }
        }
    }
    cpairs.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    let mut merged: Vec<(u32, u32, f64)> = Vec::new();
    for (a, b, w) in cpairs {
        match merged.last_mut() {
            Some(&mut (la, lb, ref mut mw)) if la == a && lb == b => *mw += w,
            _ => merged.push((a, b, w)),
        }
    }
    // every symmetric edge was visited twice => halve
    for m in &mut merged {
        m.2 /= 2.0;
    }
    let mut deg = vec![0usize; cnv];
    for &(a, b, _) in &merged {
        deg[a as usize] += 1;
        deg[b as usize] += 1;
    }
    let mut offsets = vec![0usize; cnv + 1];
    for i in 0..cnv {
        offsets[i + 1] = offsets[i] + deg[i];
    }
    let mut pos = offsets.clone();
    let mut neigh = vec![0u32; merged.len() * 2];
    let mut w = vec![0f64; merged.len() * 2];
    for &(a, b, wt) in &merged {
        neigh[pos[a as usize]] = b;
        w[pos[a as usize]] = wt;
        pos[a as usize] += 1;
        neigh[pos[b as usize]] = a;
        w[pos[b as usize]] = wt;
        pos[b as usize] += 1;
    }
    level.coarse_map = coarse_map;
    Level { offsets, neigh, w, vw: cvw, coarse_map: Vec::new() }
}

/// Greedy region growing on the coarsest graph: seed k fronts, expand the
/// currently lightest part through its heaviest frontier edge.
fn initial_partition(level: &Level, k: usize, rng: &mut Rng) -> Vec<u32> {
    let nv = level.nv();
    let total_w: f64 = level.vw.iter().sum();
    let target = total_w / k as usize as f64;
    let mut assign = vec![u32::MAX; nv];
    let mut part_w = vec![0f64; k];
    // spread seeds: pick randomly but prefer unassigned far vertices
    let mut seeds: Vec<usize> = Vec::new();
    let mut tries = 0;
    while seeds.len() < k.min(nv) && tries < 50 * k {
        let c = rng.index(nv);
        if assign[c] == u32::MAX {
            let p = seeds.len() as u32;
            assign[c] = p;
            part_w[p as usize] += level.vw[c];
            seeds.push(c);
        }
        tries += 1;
    }
    // frontier per part
    let mut frontier: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (p, &s) in seeds.iter().enumerate() {
        for (u, _) in level.edges(s) {
            frontier[p].push(u);
        }
    }
    let mut assigned = seeds.len();
    while assigned < nv {
        // lightest part that still has a frontier
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| part_w[a].partial_cmp(&part_w[b]).unwrap());
        let mut grew = false;
        for &p in &order {
            // pop until unassigned found
            while let Some(u) = frontier[p].pop() {
                if assign[u as usize] == u32::MAX {
                    assign[u as usize] = p as u32;
                    part_w[p] += level.vw[u as usize];
                    for (x, _) in level.edges(u as usize) {
                        if assign[x as usize] == u32::MAX {
                            frontier[p].push(x);
                        }
                    }
                    assigned += 1;
                    grew = true;
                    break;
                }
            }
            if grew {
                break;
            }
        }
        if !grew {
            // disconnected remainder: assign to lightest part
            for v in 0..nv {
                if assign[v] == u32::MAX {
                    let p = (0..k)
                        .min_by(|&a, &b| part_w[a].partial_cmp(&part_w[b]).unwrap())
                        .unwrap();
                    assign[v] = p as u32;
                    part_w[p] += level.vw[v];
                    for (x, _) in level.edges(v) {
                        if assign[x as usize] == u32::MAX {
                            frontier[p].push(x);
                        }
                    }
                    assigned += 1;
                    break;
                }
            }
        }
        let _ = target;
    }
    assign
}

/// One boundary-FM pass: move boundary vertices to the adjacent part with
/// maximal cut gain if balance allows. Returns number of moves.
fn refine_pass(
    level: &Level,
    assign: &mut [u32],
    part_w: &mut [f64],
    k: usize,
    cap: f64,
) -> usize {
    let total_w: f64 = part_w.iter().sum();
    let max_w = cap * total_w / k as f64;
    let mut moves = 0;
    for v in 0..level.nv() {
        let pv = assign[v];
        // connectivity of v to each adjacent part
        let mut conn: Vec<(u32, f64)> = Vec::new();
        for (u, w) in level.edges(v) {
            let pu = assign[u as usize];
            match conn.iter_mut().find(|(p, _)| *p == pu) {
                Some((_, cw)) => *cw += w,
                None => conn.push((pu, w)),
            }
        }
        let internal = conn.iter().find(|(p, _)| *p == pv).map_or(0.0, |&(_, w)| w);
        let mut best: Option<(u32, f64)> = None;
        for &(p, w) in &conn {
            if p == pv {
                continue;
            }
            let gain = w - internal;
            if gain > 1e-12
                && part_w[p as usize] + level.vw[v] <= max_w
                && best.map_or(true, |(_, bg)| gain > bg)
            {
                best = Some((p, gain));
            }
        }
        if let Some((p, _)) = best {
            part_w[pv as usize] -= level.vw[v];
            part_w[p as usize] += level.vw[v];
            assign[v] = p;
            moves += 1;
        }
    }
    moves
}

/// Multilevel k-way partition of `g`. Returns a vertex->part assignment.
pub fn metis_partition(g: &Graph, k: usize, cfg: &MetisConfig) -> Vec<u32> {
    assert!(k > 0);
    let nv = g.num_vertices();
    if k == 1 {
        return vec![0; nv];
    }
    if nv <= k {
        return (0..nv).map(|v| (v % k) as u32).collect();
    }
    let mut rng = Rng::new(cfg.seed);
    let mut levels: Vec<Level> = vec![undirected_view(g)];
    // coarsen
    loop {
        let cur_nv = levels.last().unwrap().nv();
        if cur_nv <= cfg.coarse_factor * k {
            break;
        }
        let coarse = {
            let cur = levels.last_mut().unwrap();
            coarsen(cur, &mut rng)
        };
        // matching stalled (e.g. star graphs): stop
        if coarse.nv() as f64 > 0.95 * cur_nv as f64 {
            levels.push(coarse);
            break;
        }
        levels.push(coarse);
    }
    // initial partition on coarsest
    let coarsest = levels.last().unwrap();
    let mut assign = initial_partition(coarsest, k, &mut rng);
    let mut part_w = vec![0f64; k];
    for v in 0..coarsest.nv() {
        part_w[assign[v] as usize] += coarsest.vw[v];
    }
    for _ in 0..cfg.refine_passes {
        if refine_pass(coarsest, &mut assign, &mut part_w, k, cfg.balance_cap) == 0 {
            break;
        }
    }
    // uncoarsen + refine
    for li in (0..levels.len() - 1).rev() {
        let fine = &levels[li];
        let mut fine_assign = vec![0u32; fine.nv()];
        for v in 0..fine.nv() {
            fine_assign[v] = assign[fine.coarse_map[v] as usize];
        }
        assign = fine_assign;
        let mut part_w = vec![0f64; k];
        for v in 0..fine.nv() {
            part_w[assign[v] as usize] += fine.vw[v];
        }
        for _ in 0..cfg.refine_passes {
            if refine_pass(fine, &mut assign, &mut part_w, k, cfg.balance_cap) == 0 {
                break;
            }
        }
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::partition::{hash_partition, stats::PartitionStats};

    #[test]
    fn covers_all_parts_and_vertices() {
        let g = generators::road(30, 30, 1);
        let a = metis_partition(&g, 6, &MetisConfig::default());
        assert_eq!(a.len(), 900);
        let mut seen = vec![false; 6];
        for &p in &a {
            assert!(p < 6);
            seen[p as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "some part empty");
    }

    #[test]
    fn beats_hash_on_structured_graphs() {
        let g = generators::road(40, 40, 2);
        let m = metis_partition(&g, 8, &MetisConfig::default());
        let h = hash_partition(&g, 8);
        let sm = PartitionStats::compute(&g, &m, 8);
        let sh = PartitionStats::compute(&g, &h, 8);
        assert!(
            sm.edge_cut * 3 < sh.edge_cut,
            "metis cut {} not << hash cut {}",
            sm.edge_cut,
            sh.edge_cut
        );
    }

    #[test]
    fn balance_within_cap() {
        let g = generators::powerlaw(2000, 5, 3);
        let cfg = MetisConfig::default();
        let a = metis_partition(&g, 10, &cfg);
        let s = PartitionStats::compute(&g, &a, 10);
        // initial partition may overshoot slightly; refine keeps it sane
        assert!(s.balance < 1.8, "balance {}", s.balance);
    }

    #[test]
    fn k1_and_tiny_graphs() {
        let g = generators::erdos_renyi(5, 6, 1);
        assert_eq!(metis_partition(&g, 1, &MetisConfig::default()), vec![0; 5]);
        let a = metis_partition(&g, 8, &MetisConfig::default());
        assert!(a.iter().all(|&p| p < 8));
    }

    #[test]
    fn deterministic_for_seed() {
        let g = generators::delaunay_like(20, 20, 4);
        let cfg = MetisConfig::default();
        assert_eq!(metis_partition(&g, 4, &cfg), metis_partition(&g, 4, &cfg));
    }

    #[test]
    fn refine_reduces_cut_on_grid() {
        // sanity on internals: a full pipeline cut should be near-linear
        // in the grid perimeter, far below random
        let g = generators::delaunay_like(32, 32, 7);
        let a = metis_partition(&g, 4, &MetisConfig::default());
        let s = PartitionStats::compute(&g, &a, 4);
        assert!(s.cut_fraction < 0.15, "{s}");
    }
}
