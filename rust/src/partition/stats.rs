//! Partition quality metrics: edge cut, balance, boundary-vertex ratio,
//! and the per-partition locality scores that seed the adaptive hybrid
//! scheduler ([`crate::engine::HybridPolicy::Adaptive`]).

use crate::graph::{DistGraph, Graph, VertexId};

/// Quality summary of a partition assignment.
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionStats {
    /// Number of partitions the assignment targets.
    pub num_parts: usize,
    /// Directed edges whose endpoints lie in different partitions.
    pub edge_cut: usize,
    /// Fraction of edges cut.
    pub cut_fraction: f64,
    /// max part size / average part size (1.0 = perfectly balanced).
    pub balance: f64,
    /// Vertices with at least one in-edge from another partition
    /// (GraphHP boundary vertices, Def. 1).
    pub boundary_vertices: usize,
    /// Part sizes.
    pub sizes: Vec<usize>,
}

impl PartitionStats {
    /// Compute stats for `assignment` over `g` — one sequential O(V+E)
    /// analysis pass, independent of the (possibly threaded) engine
    /// runtime. Boundary classification matches [`DistGraph`]'s
    /// Definition 1 exactly: a vertex counts as boundary iff it has an
    /// in-edge from another partition.
    pub fn compute(g: &Graph, assignment: &[u32], num_parts: usize) -> PartitionStats {
        assert_eq!(assignment.len(), g.num_vertices());
        let mut sizes = vec![0usize; num_parts];
        for &p in assignment {
            sizes[p as usize] += 1;
        }
        let mut cut = 0usize;
        let mut boundary = vec![false; g.num_vertices()];
        for v in 0..g.num_vertices() as VertexId {
            let pv = assignment[v as usize];
            for &t in g.out_edges(v).0 {
                if assignment[t as usize] != pv {
                    cut += 1;
                    boundary[t as usize] = true;
                }
            }
        }
        let ne = g.num_edges().max(1);
        let avg = g.num_vertices() as f64 / num_parts as f64;
        let max = *sizes.iter().max().unwrap_or(&0) as f64;
        PartitionStats {
            num_parts,
            edge_cut: cut,
            cut_fraction: cut as f64 / ne as f64,
            balance: if avg > 0.0 { max / avg } else { 1.0 },
            boundary_vertices: boundary.iter().filter(|&&b| b).count(),
            sizes,
        }
    }
}

/// Per-partition locality summary over a built [`DistGraph`] — the
/// static signal that seeds the adaptive hybrid scheduler's initial
/// per-partition state (high locality → boundary vertices join local
/// phases; low locality → they sit out).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartitionLocality {
    /// Partition index.
    pub partition: u32,
    /// Vertices owned by the partition.
    pub vertices: usize,
    /// Boundary vertices (Definition 1) among them.
    pub boundary_vertices: usize,
    /// Edges with both endpoints inside the partition.
    pub internal_edges: usize,
    /// Out-edges leaving the partition.
    pub cut_out: usize,
    /// In-edges arriving from other partitions.
    pub cut_in: usize,
}

impl PartitionLocality {
    /// Locality score in `[0, 1]`: internal edges over all edges
    /// incident to the partition (internal + outgoing cut + incoming
    /// cut). An edgeless partition scores 1.0 — there is no
    /// cross-partition traffic to pay for.
    pub fn score(&self) -> f64 {
        let total = self.internal_edges + self.cut_out + self.cut_in;
        if total == 0 {
            1.0
        } else {
            self.internal_edges as f64 / total as f64
        }
    }

    /// Boundary vertices over owned vertices (0.0 for an empty
    /// partition).
    pub fn boundary_ratio(&self) -> f64 {
        if self.vertices == 0 {
            0.0
        } else {
            self.boundary_vertices as f64 / self.vertices as f64
        }
    }
}

/// Compute every partition's [`PartitionLocality`], in partition order.
/// O(parts) — no edge pass: vertex/boundary/internal/cut-out counts
/// come straight from the per-partition counts precomputed at build
/// time, and the incoming-cut tally reads the routing epoch's `cut_in`
/// column, which [`DistGraph::apply_migration`] maintains in lockstep
/// with the epoch. The adaptive scheduler and the online repartitioner
/// can therefore re-seed at every barrier without rescanning routes.
/// In debug builds the former full route rescan runs as an oracle
/// against the precomputed tallies.
pub fn partition_localities(dg: &DistGraph) -> Vec<PartitionLocality> {
    let out: Vec<PartitionLocality> = dg
        .parts
        .iter()
        .map(|p| PartitionLocality {
            partition: p.part,
            vertices: p.num_vertices(),
            boundary_vertices: p.num_boundary(),
            internal_edges: p.num_internal_edges(),
            cut_out: p.num_edges() - p.num_internal_edges(),
            cut_in: dg.routing.cut_in[p.part as usize] as usize,
        })
        .collect();
    #[cfg(debug_assertions)]
    {
        let oracle = rescan_cut_in(dg);
        let got: Vec<usize> = out.iter().map(|l| l.cut_in).collect();
        assert_eq!(
            got, oracle,
            "invariant violated: RoutingEpoch::cut_in tallies disagree with a route rescan"
        );
    }
    out
}

/// The pre-epoch incoming-cut computation — one pass streaming the
/// routes alone (raw SoA column, or route-only decode on compressed
/// storage). Kept as the debug-build oracle for the incremental
/// `RoutingEpoch::cut_in` column.
#[cfg(debug_assertions)]
fn rescan_cut_in(dg: &DistGraph) -> Vec<usize> {
    let mut cut_in = vec![0usize; dg.parts.len()];
    for p in &dg.parts {
        for lv in 0..p.num_vertices() {
            for r in p.out_edges(lv).route_iter() {
                let tp = r.part();
                if tp != p.part {
                    cut_in[tp as usize] += 1;
                }
            }
        }
    }
    cut_in
}

impl std::fmt::Display for PartitionStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "parts={} cut={} ({:.1}%) balance={:.3} boundary={}",
            self.num_parts,
            self.edge_cut,
            100.0 * self.cut_fraction,
            self.balance,
            self.boundary_vertices
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::partition::hash_partition;

    #[test]
    fn single_part_has_zero_cut() {
        let g = generators::erdos_renyi(100, 400, 1);
        let s = PartitionStats::compute(&g, &vec![0; 100], 1);
        assert_eq!(s.edge_cut, 0);
        assert_eq!(s.boundary_vertices, 0);
        assert_eq!(s.balance, 1.0);
    }

    #[test]
    fn hash_cut_is_high_on_structured_graph() {
        let g = generators::road(30, 30, 1);
        let a = hash_partition(&g, 8);
        let s = PartitionStats::compute(&g, &a, 8);
        // random partition of a grid cuts ~(1 - 1/k) of edges
        assert!(s.cut_fraction > 0.7, "{s}");
    }

    #[test]
    fn stats_match_distgraph() {
        let g = generators::powerlaw(500, 4, 2);
        let a = hash_partition(&g, 5);
        let s = PartitionStats::compute(&g, &a, 5);
        let dg = crate::graph::DistGraph::new(&g, &a, 5);
        assert_eq!(s.edge_cut, dg.edge_cut());
        assert_eq!(s.boundary_vertices, dg.num_boundary());
    }

    // ------------------------------------------- hand-built exact cases

    /// 0 -> 1 -> 2 -> 3 (a directed path).
    fn path4() -> Graph {
        let mut b = crate::graph::GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        b.add_edge(2, 3, 1.0);
        b.build()
    }

    #[test]
    fn path_split_has_exact_cut_and_boundary() {
        let g = path4();
        // {0,1} | {2,3}: only edge 1->2 crosses; vertex 2 is boundary
        let s = PartitionStats::compute(&g, &[0, 0, 1, 1], 2);
        assert_eq!(s.edge_cut, 1);
        assert_eq!(s.boundary_vertices, 1);
        assert!((s.cut_fraction - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.sizes, vec![2, 2]);
        assert_eq!(s.balance, 1.0);
    }

    #[test]
    fn alternating_split_cuts_everything() {
        let g = path4();
        // {0,2} | {1,3}: every edge crosses; every target is boundary
        let s = PartitionStats::compute(&g, &[0, 1, 0, 1], 2);
        assert_eq!(s.edge_cut, 3);
        assert_eq!(s.boundary_vertices, 3);
        assert!((s.cut_fraction - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_graph_stats_are_finite() {
        let g = Graph { offsets: vec![0], targets: vec![], weights: vec![] };
        let s = PartitionStats::compute(&g, &[], 3);
        assert_eq!(s.edge_cut, 0);
        assert_eq!(s.boundary_vertices, 0);
        assert_eq!(s.balance, 1.0, "empty graph must not divide by zero");
        assert_eq!(s.sizes, vec![0, 0, 0]);
    }

    #[test]
    fn empty_partition_counts_as_zero_size() {
        let g = path4();
        // partition 1 of 3 owns nothing
        let s = PartitionStats::compute(&g, &[0, 0, 2, 2], 3);
        assert_eq!(s.sizes, vec![2, 0, 2]);
        assert_eq!(s.edge_cut, 1);
        assert!((s.balance - 1.5).abs() < 1e-12, "max 2 / avg 4/3");
    }

    // ------------------------------------------------ locality scores

    #[test]
    fn locality_exact_on_hand_built_split() {
        let g = path4();
        let dg = crate::graph::DistGraph::new(&g, &[0, 0, 1, 1], 2);
        let loc = partition_localities(&dg);
        assert_eq!(loc.len(), 2);
        // partition 0: internal 0->1, cut_out 1->2, no cut_in
        assert_eq!(loc[0].internal_edges, 1);
        assert_eq!(loc[0].cut_out, 1);
        assert_eq!(loc[0].cut_in, 0);
        assert!((loc[0].score() - 0.5).abs() < 1e-12);
        // partition 1: internal 2->3, cut_in 1->2
        assert_eq!(loc[1].internal_edges, 1);
        assert_eq!(loc[1].cut_out, 0);
        assert_eq!(loc[1].cut_in, 1);
        assert!((loc[1].score() - 0.5).abs() < 1e-12);
        assert_eq!(loc[1].boundary_vertices, 1);
        assert!((loc[1].boundary_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn single_partition_locality_is_one() {
        let g = generators::erdos_renyi(40, 120, 9);
        let dg = crate::graph::DistGraph::new(&g, &vec![0; 40], 1);
        let loc = partition_localities(&dg);
        assert_eq!(loc.len(), 1);
        assert_eq!(loc[0].score(), 1.0);
        assert_eq!(loc[0].cut_out + loc[0].cut_in, 0);
        assert_eq!(loc[0].boundary_ratio(), 0.0);
    }

    #[test]
    fn empty_partition_locality_is_neutral() {
        let g = path4();
        // all vertices in partition 0 of 2: partition 1 is empty
        let dg = crate::graph::DistGraph::new(&g, &[0, 0, 0, 0], 2);
        let loc = partition_localities(&dg);
        assert_eq!(loc[1].vertices, 0);
        assert_eq!(loc[1].score(), 1.0, "edgeless partition scores 1.0");
        assert_eq!(loc[1].boundary_ratio(), 0.0);
        assert_eq!(loc[0].score(), 1.0);
    }

    #[test]
    fn locality_stays_exact_across_migration() {
        // the O(parts) path reads the routing epoch's cut_in column; a
        // migrated view must report the same localities as a fresh
        // build of the migrated assignment
        let g = generators::powerlaw(300, 4, 5);
        let a = hash_partition(&g, 3);
        let dg = crate::graph::DistGraph::new(&g, &a, 3);
        let plan = crate::graph::MigrationPlan {
            epoch: 1,
            moves: vec![(1, (a[1] + 1) % 3), (7, (a[7] + 1) % 3)],
        };
        let m = dg.apply_migration(&plan);
        let fresh = crate::graph::DistGraph::new(&g, &m.assignment(), 3);
        assert_eq!(partition_localities(&m), partition_localities(&fresh));
    }

    #[test]
    fn locality_internal_plus_cut_covers_all_edges() {
        let g = generators::powerlaw(400, 4, 5);
        let a = hash_partition(&g, 4);
        let dg = crate::graph::DistGraph::new(&g, &a, 4);
        let loc = partition_localities(&dg);
        let internal: usize = loc.iter().map(|l| l.internal_edges).sum();
        let cut_out: usize = loc.iter().map(|l| l.cut_out).sum();
        let cut_in: usize = loc.iter().map(|l| l.cut_in).sum();
        assert_eq!(cut_out, cut_in, "every cut edge leaves one part and enters another");
        assert_eq!(internal + cut_out, g.num_edges());
        assert_eq!(cut_out, dg.edge_cut());
        for l in &loc {
            let s = l.score();
            assert!((0.0..=1.0).contains(&s), "score {s} out of range");
        }
    }
}
