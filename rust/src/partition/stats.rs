//! Partition quality metrics: edge cut, balance, boundary-vertex ratio.

use crate::graph::{Graph, VertexId};

/// Quality summary of a partition assignment.
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionStats {
    pub num_parts: usize,
    /// Directed edges whose endpoints lie in different partitions.
    pub edge_cut: usize,
    /// Fraction of edges cut.
    pub cut_fraction: f64,
    /// max part size / average part size (1.0 = perfectly balanced).
    pub balance: f64,
    /// Vertices with at least one in-edge from another partition
    /// (GraphHP boundary vertices, Def. 1).
    pub boundary_vertices: usize,
    /// Part sizes.
    pub sizes: Vec<usize>,
}

impl PartitionStats {
    /// Compute stats for `assignment` over `g`.
    pub fn compute(g: &Graph, assignment: &[u32], num_parts: usize) -> PartitionStats {
        assert_eq!(assignment.len(), g.num_vertices());
        let mut sizes = vec![0usize; num_parts];
        for &p in assignment {
            sizes[p as usize] += 1;
        }
        let mut cut = 0usize;
        let mut boundary = vec![false; g.num_vertices()];
        for v in 0..g.num_vertices() as VertexId {
            let pv = assignment[v as usize];
            for &t in g.out_edges(v).0 {
                if assignment[t as usize] != pv {
                    cut += 1;
                    boundary[t as usize] = true;
                }
            }
        }
        let ne = g.num_edges().max(1);
        let avg = g.num_vertices() as f64 / num_parts as f64;
        let max = *sizes.iter().max().unwrap_or(&0) as f64;
        PartitionStats {
            num_parts,
            edge_cut: cut,
            cut_fraction: cut as f64 / ne as f64,
            balance: if avg > 0.0 { max / avg } else { 1.0 },
            boundary_vertices: boundary.iter().filter(|&&b| b).count(),
            sizes,
        }
    }
}

impl std::fmt::Display for PartitionStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "parts={} cut={} ({:.1}%) balance={:.3} boundary={}",
            self.num_parts,
            self.edge_cut,
            100.0 * self.cut_fraction,
            self.balance,
            self.boundary_vertices
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::partition::hash_partition;

    #[test]
    fn single_part_has_zero_cut() {
        let g = generators::erdos_renyi(100, 400, 1);
        let s = PartitionStats::compute(&g, &vec![0; 100], 1);
        assert_eq!(s.edge_cut, 0);
        assert_eq!(s.boundary_vertices, 0);
        assert_eq!(s.balance, 1.0);
    }

    #[test]
    fn hash_cut_is_high_on_structured_graph() {
        let g = generators::road(30, 30, 1);
        let a = hash_partition(&g, 8);
        let s = PartitionStats::compute(&g, &a, 8);
        // random partition of a grid cuts ~(1 - 1/k) of edges
        assert!(s.cut_fraction > 0.7, "{s}");
    }

    #[test]
    fn stats_match_distgraph() {
        let g = generators::powerlaw(500, 4, 2);
        let a = hash_partition(&g, 5);
        let s = PartitionStats::compute(&g, &a, 5);
        let dg = crate::graph::DistGraph::new(&g, &a, 5);
        assert_eq!(s.edge_cut, dg.edge_cut());
        assert_eq!(s.boundary_vertices, dg.num_boundary());
    }
}
