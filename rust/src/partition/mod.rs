//! Graph partitioners.
//!
//! The paper partitions with ParMetis; hash partitioning
//! (`hash(id) mod k`) is Hama's default. We provide:
//!
//! - [`hash_partition`] — the Hama default (high edge-cut baseline);
//! - [`range_partition`] — contiguous ranges (good for generator graphs
//!   whose ids are spatially ordered, e.g. grids);
//! - [`metis`] — a from-scratch multilevel k-way partitioner (heavy-edge
//!   matching coarsening → greedy region-growing initial partition →
//!   boundary FM refinement), the ParMetis stand-in.

pub mod metis;
pub mod stats;

pub use metis::{metis_partition, MetisConfig};
pub use stats::{partition_localities, PartitionLocality, PartitionStats};

use crate::graph::{Graph, VertexId};

/// Hama's default: `hash(id) mod k`. We use a splitmix-style bit mix so
/// consecutive ids scatter (plain `id % k` would behave like range
/// partitioning on generator graphs and hide the paper's point).
pub fn hash_partition(g: &Graph, k: usize) -> Vec<u32> {
    assert!(k > 0);
    (0..g.num_vertices() as VertexId)
        .map(|v| {
            let mut z = (v as u64).wrapping_add(0x9e3779b97f4a7c15);
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            ((z ^ (z >> 31)) % k as u64) as u32
        })
        .collect()
}

/// Contiguous equal ranges of vertex ids.
pub fn range_partition(g: &Graph, k: usize) -> Vec<u32> {
    assert!(k > 0);
    let n = g.num_vertices();
    let per = n.div_ceil(k);
    (0..n).map(|v| ((v / per.max(1)) as u32).min(k as u32 - 1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn hash_covers_all_parts_roughly_evenly() {
        let g = generators::erdos_renyi(1000, 3000, 1);
        let a = hash_partition(&g, 7);
        assert_eq!(a.len(), 1000);
        let mut counts = [0usize; 7];
        for &p in &a {
            assert!(p < 7);
            counts[p as usize] += 1;
        }
        for &c in &counts {
            assert!(c > 80 && c < 220, "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn range_partition_is_contiguous_and_covers() {
        let g = generators::erdos_renyi(100, 300, 2);
        let a = range_partition(&g, 4);
        for w in a.windows(2) {
            assert!(w[1] >= w[0]); // monotone
        }
        assert_eq!(*a.last().unwrap(), 3);
        assert_eq!(a[0], 0);
    }

    #[test]
    fn range_handles_k_bigger_than_n() {
        let g = generators::erdos_renyi(3, 2, 3);
        let a = range_partition(&g, 8);
        assert!(a.iter().all(|&p| p < 8));
    }
}
