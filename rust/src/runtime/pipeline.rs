//! Accelerated GraphHP pipelines: the end-to-end composition of all
//! three layers.
//!
//! These drivers run the GraphHP hybrid iteration with the *local phase
//! executed by the AOT-compiled JAX/Pallas programs* (L1+L2) and the
//! global phase — cross-partition message derivation, combining,
//! delivery, barriers, termination — owned by the Rust coordinator (L3).
//! They are numerically interchangeable with the scalar
//! [`crate::engine::graphhp`] engine running
//! [`crate::algorithms::IncrementalPageRank`] / [`crate::algorithms::Sssp`]
//! (tested in `rust/tests/runtime_xla.rs` and used by
//! `examples/e2e_accelerated.rs`).

use std::time::Duration;

use anyhow::{bail, Result};

use crate::algorithms::pagerank::{BASE, DAMPING};
use crate::algorithms::sssp::INF;
use crate::engine::netsim::{SuperstepClock, WorkerComm};
use crate::engine::{EngineConfig, Metrics, RunResult};
use crate::graph::DistGraph;

use super::accel::DenseLocalAccel;
use super::{LoadedPhase, XlaRuntime};

/// Per-message wire cost used by the pipelines (f32 payload + header).
const MSG_BYTES: u64 = 12;

/// Build one accelerator per partition; fails if any partition exceeds
/// the artifact tile size.
pub fn build_accels(dg: &DistGraph, n: usize, damping: f32) -> Result<Vec<DenseLocalAccel>> {
    dg.parts.iter().map(|p| DenseLocalAccel::new(p, n, damping)).collect()
}

/// GraphHP incremental PageRank with XLA local phases.
///
/// Semantics follow Alg. 5 under the hybrid model: all vertices start
/// with a pending delta of `BASE`; every global iteration runs each
/// partition's local phase to convergence (fused K-step XLA scans), then
/// exchanges the damped accumulated outflow across partition boundaries;
/// messages below `tolerance` are not sent (the program's halting rule).
pub fn run_pagerank_accelerated(
    runtime: &XlaRuntime,
    dg: &DistGraph,
    tolerance: f32,
    cfg: &EngineConfig,
) -> Result<RunResult<f64>> {
    let phase: LoadedPhase = runtime.load_phase("pagerank_local")?;
    let n = phase.spec.n;
    let mut accels = build_accels(dg, n, DAMPING as f32)?;

    let np = dg.num_parts();
    // The scan model adds M·delta to rank as it derives it, so mass fed
    // INTO the phase must be pre-credited: the initial BASE here, remote
    // deliveries below.
    let mut rank: Vec<Vec<f32>> =
        dg.parts.iter().map(|p| vec![BASE as f32; p.num_vertices()]).collect();
    let mut delta: Vec<Vec<f32>> =
        dg.parts.iter().map(|p| vec![BASE as f32; p.num_vertices()]).collect();

    let mut metrics = Metrics::default();
    let mut clock = SuperstepClock::new();

    for _iter in 0..cfg.limits.max_iterations {
        // incoming per partition, accumulated (sum-combined) per vertex
        let mut incoming: Vec<Vec<f32>> =
            dg.parts.iter().map(|p| vec![0f32; p.num_vertices()]).collect();
        let mut any_messages = false;

        for p in 0..np {
            let t0 = std::time::Instant::now();
            // ---- local phase (L1+L2 on XLA) ------------------------
            let (acc, invocations) = accels[p].pagerank_local_phase(
                runtime,
                &phase,
                &mut rank[p],
                &mut delta[p],
                tolerance,
                10_000,
            )?;
            metrics.supersteps_total += invocations as u64 * phase.spec.steps as u64;
            // ---- derive cross-partition messages (L3) --------------
            let part = &dg.parts[p];
            let mut msgs = 0u64;
            let mut peers: Vec<bool> = vec![false; np];
            for lv in 0..part.num_vertices() {
                let mass = acc[lv];
                let deg = part.out_degree[lv];
                if deg == 0 || mass <= 0.0 {
                    continue;
                }
                let share = DAMPING as f32 * mass / deg as f32;
                if share < tolerance {
                    continue; // halting rule of Alg. 5
                }
                for e in part.out_edges(lv) {
                    if e.target_part != part.part {
                        incoming[e.target_part as usize][e.target_local as usize] += share;
                        msgs += 1;
                        peers[e.target_part as usize] = true;
                        any_messages = true;
                    }
                }
            }
            let compute = cfg.net.scale_compute(t0.elapsed());
            let comm = WorkerComm {
                messages: msgs,
                bytes: msgs * MSG_BYTES,
                peer_pairs: peers.iter().filter(|&&x| x).count() as u64,
            };
            metrics.network_messages += msgs;
            metrics.network_bytes += comm.bytes;
            clock.record_worker(compute, cfg.net.comm_time(&comm));
        }

        clock.barrier(&cfg.net, &mut metrics);
        metrics.global_iterations += 1;

        if !any_messages {
            break;
        }
        for p in 0..np {
            for (lv, &m) in incoming[p].iter().enumerate() {
                if m > 0.0 {
                    rank[p][lv] += m; // apply (Alg. 5 `value += update`)
                    delta[p][lv] += m; // and queue for propagation
                }
            }
        }
    }

    // gather to global ids as f64 (engine-compatible)
    let per_part: Vec<Vec<f64>> = rank
        .iter()
        .map(|r| r.iter().map(|&x| x as f64).collect())
        .collect();
    let values = crate::engine::gather_values(dg, &per_part);
    Ok(RunResult { values, metrics, trace: Default::default(), chaos: None })
}

/// GraphHP SSSP with XLA min-plus local phases.
pub fn run_sssp_accelerated(
    runtime: &XlaRuntime,
    dg: &DistGraph,
    source: u32,
    cfg: &EngineConfig,
) -> Result<RunResult<f32>> {
    let phase: LoadedPhase = runtime.load_phase("sssp_local")?;
    let n = phase.spec.n;
    let mut accels = build_accels(dg, n, DAMPING as f32)?;
    if source as usize >= dg.num_vertices {
        bail!("source {source} out of range");
    }

    let np = dg.num_parts();
    let mut dist: Vec<Vec<f32>> =
        dg.parts.iter().map(|p| vec![INF; p.num_vertices()]).collect();
    {
        let (sp, sl) = dg.routing.location[source as usize];
        dist[sp as usize][sl as usize] = 0.0;
    }
    // track which vertices improved since last propagation, per partition
    let mut dirty: Vec<Vec<bool>> =
        dg.parts.iter().map(|p| vec![false; p.num_vertices()]).collect();
    {
        let (sp, sl) = dg.routing.location[source as usize];
        dirty[sp as usize][sl as usize] = true;
    }

    let mut metrics = Metrics::default();
    let mut clock = SuperstepClock::new();

    for _iter in 0..cfg.limits.max_iterations {
        let mut incoming: Vec<Vec<f32>> =
            dg.parts.iter().map(|p| vec![INF; p.num_vertices()]).collect();
        let mut any_messages = false;

        for p in 0..np {
            let t0 = std::time::Instant::now();
            let part = &dg.parts[p];
            let live = part.num_vertices();
            let before: Vec<f32> = dist[p].clone();
            // run the local phase only if something is dirty
            let run_needed = dirty[p].iter().any(|&d| d);
            if run_needed {
                let (_improved, invocations) =
                    accels[p].sssp_local_phase(runtime, &phase, &mut dist[p], 10_000)?;
                metrics.supersteps_total += invocations as u64 * phase.spec.steps as u64;
            }
            // propagate improvements across partitions
            let mut msgs = 0u64;
            let mut peers: Vec<bool> = vec![false; np];
            for lv in 0..live {
                let changed = dist[p][lv] < before[lv] - 1e-9 || dirty[p][lv];
                if !changed || dist[p][lv] >= INF {
                    continue;
                }
                let d = dist[p][lv];
                for e in part.out_edges(lv) {
                    if e.target_part != part.part {
                        let cand = d + e.weight;
                        let slot =
                            &mut incoming[e.target_part as usize][e.target_local as usize];
                        if cand < *slot {
                            if *slot >= INF {
                                msgs += 1; // min-combined per destination
                            }
                            *slot = cand;
                            peers[e.target_part as usize] = true;
                            any_messages = true;
                        }
                    }
                }
                dirty[p][lv] = false;
            }
            let compute = cfg.net.scale_compute(t0.elapsed());
            let comm = WorkerComm {
                messages: msgs,
                bytes: msgs * MSG_BYTES,
                peer_pairs: peers.iter().filter(|&&x| x).count() as u64,
            };
            metrics.network_messages += msgs;
            metrics.network_bytes += comm.bytes;
            clock.record_worker(compute, cfg.net.comm_time(&comm));
        }

        clock.barrier(&cfg.net, &mut metrics);
        metrics.global_iterations += 1;

        if !any_messages {
            break;
        }
        for p in 0..np {
            for (lv, &m) in incoming[p].iter().enumerate() {
                if m < dist[p][lv] {
                    dist[p][lv] = m;
                    dirty[p][lv] = true;
                }
            }
        }
    }

    let values = crate::engine::gather_values(dg, &dist);
    Ok(RunResult { values, metrics, trace: Default::default(), chaos: None })
}

/// Wall-clock helper for perf reporting: XLA execute time of one phase
/// invocation, median of `reps`.
pub fn time_phase_invocation(
    phase: &LoadedPhase,
    reps: usize,
) -> Result<Duration> {
    let n = phase.spec.n;
    let m = vec![0.001f32; n * n];
    let r = vec![0.15f32; n];
    let d = vec![0.15f32; n];
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        let _ = phase.run_pagerank(&m, &r, &d)?;
        times.push(t0.elapsed());
    }
    times.sort();
    Ok(times[reps / 2])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::partition::{metis_partition, MetisConfig};

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn accelerated_pagerank_matches_oracle() {
        if !artifacts_dir().join("manifest.txt").exists() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let rt = XlaRuntime::new(artifacts_dir()).unwrap();
        let g = generators::powerlaw(800, 4, 21);
        let a = metis_partition(&g, 5, &MetisConfig::default());
        let dg = DistGraph::new(&g, &a, 5);
        // partitions must fit the 256 tile
        if dg.parts.iter().any(|p| p.num_vertices() > 256) {
            eprintln!("skipping: partition too large for tile");
            return;
        }
        let r =
            run_pagerank_accelerated(&rt, &dg, 1e-6, &EngineConfig::default()).unwrap();
        let want = crate::algorithms::oracle::pagerank(&g, 1e-12);
        let err: f64 = r
            .values
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / want.len() as f64;
        assert!(err < 1e-3, "avg err {err}");
        assert!(r.metrics.global_iterations > 1);
        assert!(r.metrics.network_messages > 0);
    }

    #[test]
    fn accelerated_sssp_matches_dijkstra() {
        if !artifacts_dir().join("manifest.txt").exists() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let rt = XlaRuntime::new(artifacts_dir()).unwrap();
        let g = generators::road(20, 20, 4);
        let a = metis_partition(&g, 4, &MetisConfig::default());
        let dg = DistGraph::new(&g, &a, 4);
        if dg.parts.iter().any(|p| p.num_vertices() > 256) {
            eprintln!("skipping: partition too large for tile");
            return;
        }
        let r = run_sssp_accelerated(&rt, &dg, 0, &EngineConfig::default()).unwrap();
        let want = crate::algorithms::oracle::dijkstra(&g, 0);
        for (i, (&got, &w)) in r.values.iter().zip(&want).enumerate() {
            if w.is_finite() {
                assert!((got - w as f32).abs() < 1e-2, "v{i}: {got} vs {w}");
            } else {
                assert!(got >= INF * 0.5);
            }
        }
    }
}
