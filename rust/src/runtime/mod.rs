//! XLA/PJRT runtime: loads the AOT-compiled JAX/Pallas local-phase
//! artifacts (`artifacts/*.hlo.txt`) and executes them from the Rust
//! coordinator. Python never runs on this path.
//!
//! Interchange is HLO **text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod accel;
pub mod pipeline;

pub use accel::{DenseLocalAccel, PAD_RANK_INF};
pub use pipeline::{run_pagerank_accelerated, run_sssp_accelerated};

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

/// Parameters of one AOT artifact, parsed from `artifacts/manifest.txt`.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    /// Artifact base name (matches the `.hlo.txt` file stem).
    pub name: String,
    /// Densified tile edge (partition capacity).
    pub n: usize,
    /// Pseudo-supersteps fused per invocation.
    pub steps: usize,
}

/// Parse `manifest.txt` (one line per artifact: `name n steps ins outs`).
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSpec>> {
    let mut specs = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let name = it.next().context("manifest: missing name")?.to_string();
        let n: usize = it.next().context("manifest: missing n")?.parse()?;
        let steps: usize = it.next().context("manifest: missing steps")?.parse()?;
        specs.push(ArtifactSpec { name, n, steps });
    }
    Ok(specs)
}

/// A compiled local-phase executable.
pub struct LoadedPhase {
    /// The manifest entry this executable was compiled from.
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime holding the CPU client and the compiled phases.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

impl XlaRuntime {
    /// Create a CPU PJRT client rooted at `artifacts_dir`.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
        Ok(XlaRuntime { client, artifacts_dir: artifacts_dir.into() })
    }

    /// PJRT platform name of the backing client (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload an f32 tensor to the device (kept resident across
    /// invocations — the perf-critical path caches the densified
    /// partition operator this way; see EXPERIMENTS.md §Perf).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Load + compile `<name>.hlo.txt`, cross-checking the manifest.
    pub fn load_phase(&self, name: &str) -> Result<LoadedPhase> {
        let manifest_path = self.artifacts_dir.join("manifest.txt");
        let manifest = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {manifest_path:?} — run `make artifacts` first"))?;
        let spec = parse_manifest(&manifest)?
            .into_iter()
            .find(|s| s.name == name)
            .with_context(|| format!("artifact {name} not in manifest"))?;
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        Ok(LoadedPhase { spec, exe })
    }
}

impl LoadedPhase {
    /// Execute with row-major f32 buffers; returns the tuple elements as
    /// flat f32 vectors (scalars/s32 outputs are converted to f32 via
    /// bit-faithful casts where needed by the callers).
    pub fn execute_f32(
        &self,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data);
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims_i64)?);
        }
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let outs = result.decompose_tuple()?;
        let mut vecs = Vec::with_capacity(outs.len());
        for out in outs {
            // convert whatever element type to f32 (s32 `changed` counts
            // are exact in f32 for our sizes)
            let conv = out.convert(xla::PrimitiveType::F32)?;
            vecs.push(conv.to_vec::<f32>()?);
        }
        Ok(vecs)
    }

    /// Execute with pre-uploaded device buffers for the big operands and
    /// host slices for the small ones. Buffer order must match the
    /// entry computation's parameter order.
    pub fn execute_mixed_f32(
        &self,
        runtime: &XlaRuntime,
        device_first: &xla::PjRtBuffer,
        host_rest: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(host_rest.len());
        for (data, dims) in host_rest {
            bufs.push(runtime.upload_f32(data, dims)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = vec![device_first];
        args.extend(bufs.iter());
        let mut result = self.exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0]
            .to_literal_sync()?;
        let outs = result.decompose_tuple()?;
        let mut vecs = Vec::with_capacity(outs.len());
        for out in outs {
            let conv = out.convert(xla::PrimitiveType::F32)?;
            vecs.push(conv.to_vec::<f32>()?);
        }
        Ok(vecs)
    }

    /// `run_pagerank` with the matrix resident on device.
    pub fn run_pagerank_dev(
        &self,
        runtime: &XlaRuntime,
        m_dev: &xla::PjRtBuffer,
        rank: &[f32],
        delta: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        let n = self.spec.n;
        if rank.len() != n || delta.len() != n {
            bail!("pagerank phase: bad input sizes");
        }
        let outs = self.execute_mixed_f32(
            runtime,
            m_dev,
            &[(rank, &[n, 1]), (delta, &[n, 1])],
        )?;
        if outs.len() != 4 {
            bail!("pagerank phase: expected 4 outputs, got {}", outs.len());
        }
        let mut it = outs.into_iter();
        let rank = it.next().unwrap();
        let delta = it.next().unwrap();
        let acc = it.next().unwrap();
        let linf = it.next().unwrap()[0];
        Ok((rank, delta, acc, linf))
    }

    /// `run_sssp` with the weight matrix resident on device.
    pub fn run_sssp_dev(
        &self,
        runtime: &XlaRuntime,
        w_dev: &xla::PjRtBuffer,
        d: &[f32],
    ) -> Result<(Vec<f32>, u32)> {
        let n = self.spec.n;
        if d.len() != n {
            bail!("sssp phase: bad input sizes");
        }
        let outs = self.execute_mixed_f32(runtime, w_dev, &[(d, &[n, 1])])?;
        if outs.len() != 2 {
            bail!("sssp phase: expected 2 outputs, got {}", outs.len());
        }
        let changed = outs[1][0] as u32;
        Ok((outs[0].clone(), changed))
    }

    /// Execute the `pagerank_local` phase.
    /// Inputs: m (n·n), rank (n), delta (n). Output: (rank', delta',
    /// acc, linf).
    pub fn run_pagerank(
        &self,
        m: &[f32],
        rank: &[f32],
        delta: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        let n = self.spec.n;
        if m.len() != n * n || rank.len() != n || delta.len() != n {
            bail!("pagerank phase: bad input sizes");
        }
        let outs = self.execute_f32(&[
            (m, &[n, n]),
            (rank, &[n, 1]),
            (delta, &[n, 1]),
        ])?;
        if outs.len() != 4 {
            bail!("pagerank phase: expected 4 outputs, got {}", outs.len());
        }
        let mut it = outs.into_iter();
        let rank = it.next().unwrap();
        let delta = it.next().unwrap();
        let acc = it.next().unwrap();
        let linf = it.next().unwrap()[0];
        Ok((rank, delta, acc, linf))
    }

    /// Execute the `sssp_local` phase.
    /// Inputs: w (n·n), d (n). Output: (d', changed-count).
    pub fn run_sssp(&self, w: &[f32], d: &[f32]) -> Result<(Vec<f32>, u32)> {
        let n = self.spec.n;
        if w.len() != n * n || d.len() != n {
            bail!("sssp phase: bad input sizes");
        }
        let outs = self.execute_f32(&[(w, &[n, n]), (d, &[n, 1])])?;
        if outs.len() != 2 {
            bail!("sssp phase: expected 2 outputs, got {}", outs.len());
        }
        let changed = outs[1][0] as u32;
        Ok((outs[0].clone(), changed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = "pagerank_local 256 8 m,rank,delta rank,delta,acc,linf\n\
                 sssp_local 256 8 w,d d,changed\n";
        let specs = parse_manifest(m).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0], ArtifactSpec { name: "pagerank_local".into(), n: 256, steps: 8 });
        assert_eq!(specs[1].name, "sssp_local");
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(parse_manifest("pagerank_local notanumber 8 x y").is_err());
    }
}
