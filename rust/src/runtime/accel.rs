//! Dense local-phase accelerator.
//!
//! GraphHP's key cost is the local phase — a partition-private fixed
//! point. For the value-propagation algorithms (incremental PageRank,
//! SSSP) that fixed point is linear-algebraic, so a partition whose
//! vertex count fits the AOT tile can run its *entire local phase* as one
//! (or a few) XLA executions of the scan-fused JAX/Pallas program instead
//! of the scalar message loop (DESIGN.md §5).
//!
//! This module densifies a [`PartGraph`]'s internal adjacency into the
//! fixed-size f32 tiles the artifacts expect, with the padding
//! conventions the kernels are tested for:
//! - PageRank matrix `M[i,j] = d·A[j,i]/outdeg(j)` (0 padding);
//! - SSSP weights `W[i,j] = w(j→i)` with `INF` padding.

use anyhow::{bail, Result};

use crate::algorithms::sssp::INF;
use crate::graph::PartGraph;

use super::LoadedPhase;

/// Rank value used for padded lanes so they never propagate.
pub const PAD_RANK_INF: f32 = 0.0;

/// Densified views of one partition, ready for the XLA phases.
pub struct DenseLocalAccel {
    /// Tile edge (from the artifact spec).
    pub n: usize,
    /// Live vertex count (`<= n`).
    pub live: usize,
    /// PageRank propagation matrix, row-major `n × n`.
    pub m_pagerank: Vec<f32>,
    /// SSSP min-plus weight matrix, row-major `n × n` (INF = no edge).
    pub w_sssp: Vec<f32>,
    /// Device-resident copies of the operators (uploaded once, reused
    /// across every invocation — §Perf optimization #3).
    m_dev: Option<xla::PjRtBuffer>,
    w_dev: Option<xla::PjRtBuffer>,
}

impl DenseLocalAccel {
    /// Build both dense operators for `part`. Fails if the partition has
    /// more vertices than the tile.
    pub fn new(part: &PartGraph, n: usize, damping: f32) -> Result<Self> {
        let live = part.num_vertices();
        if live > n {
            bail!("partition has {live} vertices > tile {n}; use the scalar path");
        }
        let mut m = vec![0f32; n * n];
        let mut w = vec![INF; n * n];
        for src in 0..live {
            let deg = part.out_degree[src];
            for e in part.out_edges(src) {
                if e.target_part != part.part {
                    continue; // internal edges only: the local phase
                }
                let dst = e.target_local as usize;
                // PageRank: column src scaled by d/deg, row dst
                if deg > 0 {
                    m[dst * n + src] += damping / deg as f32;
                }
                // SSSP: W[dst, src] = min weight of src->dst
                let slot = &mut w[dst * n + src];
                if e.weight < *slot {
                    *slot = e.weight;
                }
            }
        }
        Ok(DenseLocalAccel { n, live, m_pagerank: m, w_sssp: w, m_dev: None, w_dev: None })
    }

    /// Upload (once) and return the device-resident PageRank operator.
    pub fn m_device(&mut self, rt: &super::XlaRuntime) -> Result<&xla::PjRtBuffer> {
        if self.m_dev.is_none() {
            self.m_dev = Some(rt.upload_f32(&self.m_pagerank, &[self.n, self.n])?);
        }
        Ok(self.m_dev.as_ref().unwrap())
    }

    /// Upload (once) and return the device-resident SSSP operator.
    pub fn w_device(&mut self, rt: &super::XlaRuntime) -> Result<&xla::PjRtBuffer> {
        if self.w_dev.is_none() {
            self.w_dev = Some(rt.upload_f32(&self.w_sssp, &[self.n, self.n])?);
        }
        Ok(self.w_dev.as_ref().unwrap())
    }

    /// Run the partition's PageRank local phase to convergence:
    /// repeatedly invoke the K-step fused executable until the delta
    /// inf-norm drops below `tol` (or `max_invocations` runs out).
    ///
    /// `rank`/`delta` are live-length slices updated in place. Returns
    /// the accumulated per-vertex delta mass (live length) from which the
    /// coordinator derives cross-partition messages, plus the number of
    /// XLA invocations.
    pub fn pagerank_local_phase(
        &mut self,
        rt: &super::XlaRuntime,
        phase: &LoadedPhase,
        rank: &mut [f32],
        delta: &mut [f32],
        tol: f32,
        max_invocations: usize,
    ) -> Result<(Vec<f32>, usize)> {
        if phase.spec.n != self.n {
            bail!("phase tile {} != accel tile {}", phase.spec.n, self.n);
        }
        let n = self.n;
        self.m_device(rt)?; // ensure resident
        let m_dev = self.m_dev.as_ref().unwrap();
        let mut r = vec![PAD_RANK_INF; n];
        let mut d = vec![0f32; n];
        r[..self.live].copy_from_slice(rank);
        d[..self.live].copy_from_slice(delta);
        let mut acc_total = vec![0f32; self.live];
        let mut invocations = 0;
        while invocations < max_invocations {
            let (nr, nd, acc, linf) = phase.run_pagerank_dev(rt, m_dev, &r, &d)?;
            invocations += 1;
            for i in 0..self.live {
                acc_total[i] += acc[i];
            }
            r = nr;
            d = nd;
            if linf < tol {
                break;
            }
        }
        rank.copy_from_slice(&r[..self.live]);
        delta.copy_from_slice(&d[..self.live]);
        Ok((acc_total, invocations))
    }

    /// Run the partition's SSSP local phase to quiescence. `dist` is a
    /// live-length slice updated in place. Returns (improved-vertex
    /// count, invocations).
    pub fn sssp_local_phase(
        &mut self,
        rt: &super::XlaRuntime,
        phase: &LoadedPhase,
        dist: &mut [f32],
        max_invocations: usize,
    ) -> Result<(usize, usize)> {
        if phase.spec.n != self.n {
            bail!("phase tile {} != accel tile {}", phase.spec.n, self.n);
        }
        let n = self.n;
        self.w_device(rt)?; // ensure resident
        let w_dev = self.w_dev.as_ref().unwrap();
        let mut d = vec![INF; n];
        d[..self.live].copy_from_slice(dist);
        let before: Vec<f32> = d[..self.live].to_vec();
        let mut invocations = 0;
        loop {
            let (nd, changed) = phase.run_sssp_dev(rt, w_dev, &d)?;
            invocations += 1;
            d = nd;
            if changed == 0 || invocations >= max_invocations {
                break;
            }
        }
        let improved = before
            .iter()
            .zip(&d[..self.live])
            .filter(|(b, a)| **a < **b - 1e-9)
            .count();
        dist.copy_from_slice(&d[..self.live]);
        Ok((improved, invocations))
    }

    /// Scalar (no-XLA) reference of the PageRank local phase — used by
    /// tests to prove the accelerated path is a pure optimization.
    pub fn pagerank_local_phase_scalar(
        &self,
        rank: &mut [f32],
        delta: &mut [f32],
        tol: f32,
        max_steps: usize,
    ) -> Vec<f32> {
        let n = self.n;
        let live = self.live;
        let mut acc_total = vec![0f32; live];
        let mut d = vec![0f32; n];
        d[..live].copy_from_slice(delta);
        for _ in 0..max_steps {
            for i in 0..live {
                acc_total[i] += d[i];
            }
            let mut nd = vec![0f32; n];
            for i in 0..live {
                let row = &self.m_pagerank[i * n..i * n + live];
                let mut s = 0f32;
                for j in 0..live {
                    s += row[j] * d[j];
                }
                nd[i] = s;
                rank[i] += s;
            }
            let linf = nd[..live].iter().fold(0f32, |a, &b| a.max(b.abs()));
            d = nd;
            if linf < tol {
                break;
            }
        }
        delta.copy_from_slice(&d[..live]);
        acc_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, DistGraph};
    use crate::partition::hash_partition;

    #[test]
    fn densify_shapes_and_padding() {
        let g = generators::erdos_renyi(30, 120, 3);
        let a = hash_partition(&g, 2);
        let dg = DistGraph::new(&g, &a, 2);
        let acc = DenseLocalAccel::new(&dg.parts[0], 64, 0.85).unwrap();
        assert_eq!(acc.n, 64);
        assert_eq!(acc.live, dg.parts[0].num_vertices());
        // padded region of W stays INF
        for i in acc.live..64 {
            for j in 0..64 {
                assert_eq!(acc.w_sssp[i * 64 + j], INF);
            }
        }
        // column sums of M are <= damping (only internal edges present)
        for j in 0..acc.live {
            let col: f32 = (0..acc.live).map(|i| acc.m_pagerank[i * 64 + j]).sum();
            assert!(col <= 0.85 + 1e-5, "col {j} sums to {col}");
        }
    }

    #[test]
    fn rejects_oversized_partition() {
        let g = generators::erdos_renyi(100, 200, 1);
        let dg = DistGraph::new(&g, &vec![0; 100], 1);
        assert!(DenseLocalAccel::new(&dg.parts[0], 64, 0.85).is_err());
    }

    #[test]
    fn scalar_local_phase_drains_delta() {
        let g = generators::powerlaw(50, 3, 5);
        let dg = DistGraph::new(&g, &vec![0; 50], 1);
        let acc = DenseLocalAccel::new(&dg.parts[0], 64, 0.85).unwrap();
        let mut rank = vec![0.15f32; 50];
        let mut delta = vec![0.15f32; 50];
        let acc_mass = acc.pagerank_local_phase_scalar(&mut rank, &mut delta, 1e-7, 10_000);
        assert!(delta.iter().all(|&d| d.abs() < 1e-6));
        assert!(acc_mass.iter().sum::<f32>() > 0.0);
        assert!(rank.iter().all(|&r| r >= 0.15 - 1e-6));
    }
}
