//! `detlint` — the repo's determinism-contract linter.
//!
//! The whole platform rests on one oracle: a threaded run is bit-for-bit
//! identical to a sequential one (`tests/parallel_equivalence.rs`). The
//! conventions that make that hold — no hash-ordered iteration in the
//! deterministic core, wall clocks only at reporting sites, every
//! `begin_step` paired with a `commit_step`/`abort_step_carryover`,
//! thread creation confined to the worker runtime, no panicking
//! shortcuts in the hot path — used to live in doc comments. This module
//! turns them into machine-checked rules over a lightweight line-wise
//! tokenizer ([`scan`]); the `detlint` binary (`src/bin/detlint.rs`)
//! runs them over `rust/src` and CI fails on any unannotated violation.
//!
//! # Rules
//!
//! | id | rule |
//! |----|------|
//! | `unordered-iter` | no `HashMap`/`HashSet` (or Fx variants) in `engine/`/`partition/` without a rationale, and no iteration over one anywhere in those modules |
//! | `wall-clock` | `Instant::now`/`SystemTime` only at annotated reporting-only sites |
//! | `step-pairing` | `.begin_step`/`.begin_step_into` lexically paired with `.commit_step`/`.abort_step_carryover` in the same function |
//! | `thread-confinement` | thread creation (`thread::spawn`/`scope`/`Builder`) only in `engine/worker.rs` |
//! | `unwrap-hot-path` | no `.unwrap()`/`.expect(` in `engine/{worker,messages,state}.rs` outside `#[cfg(test)]` |
//! | `stale-route` | no `let` binding of `EdgeRoute`/location-table/route-column data before a `.commit_step` in the same function (routing state is epoch-scoped; `engine/worker.rs` is the sanctioned reader and exempt) |
//! | `annotation` | every suppression names a known rule and carries a reason (never suppressible) |
//!
//! # Suppressing a finding
//!
//! ```text
//! // detlint: allow(<rule>) — <reason>
//! ```
//!
//! on the offending line or on its own comment line directly above.
//! A reason is mandatory; an allow without one is inert and itself
//! reported. `#[cfg(test)]` regions are exempt from every rule.
//!
//! # Adding a rule
//!
//! Add a file under `lint/` with a `check(&SourceFile, &mut Vec<Finding>)`,
//! a [`RuleId`] variant + name, wire it into [`lint_source`], and prove
//! it live with a fixture in `tests/detlint_rules.rs` (see
//! `docs/architecture.md`, "Correctness tooling").

use std::fmt;
use std::path::Path;

pub mod scan;

mod stale_route;
mod step_pairing;
mod thread_confinement;
mod unordered_iter;
mod unwrap_hot_path;
mod wall_clock;

/// Identifier of a determinism rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleId {
    /// R1: unordered hash containers / their iteration in the
    /// deterministic core.
    UnorderedIter,
    /// R2: wall-clock reads outside annotated reporting sites.
    WallClock,
    /// R3: unpaired step lifecycle.
    StepPairing,
    /// R4: thread creation outside the worker runtime.
    ThreadConfinement,
    /// R5: `.unwrap()`/`.expect(` in hot-path modules.
    UnwrapHotPath,
    /// R6: route/location data cached across a `.commit_step` boundary.
    StaleRoute,
    /// Meta: malformed/unknown suppression annotations (never
    /// suppressible).
    Annotation,
}

impl RuleId {
    /// The six suppressible determinism rules, in report order.
    pub const RULES: [RuleId; 6] = [
        RuleId::UnorderedIter,
        RuleId::WallClock,
        RuleId::StepPairing,
        RuleId::ThreadConfinement,
        RuleId::UnwrapHotPath,
        RuleId::StaleRoute,
    ];

    /// The kebab-case name used in reports and `allow(...)` annotations.
    pub fn name(self) -> &'static str {
        match self {
            RuleId::UnorderedIter => "unordered-iter",
            RuleId::WallClock => "wall-clock",
            RuleId::StepPairing => "step-pairing",
            RuleId::ThreadConfinement => "thread-confinement",
            RuleId::UnwrapHotPath => "unwrap-hot-path",
            RuleId::StaleRoute => "stale-route",
            RuleId::Annotation => "annotation",
        }
    }
}

impl fmt::Display for RuleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One rule violation.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Which rule fired.
    pub rule: RuleId,
    /// Path relative to the scanned root, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable description.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// A scanned file plus the path predicates the rules dispatch on.
pub(crate) struct SourceFile {
    pub path: String,
    pub scanned: scan::Scanned,
}

impl SourceFile {
    /// True when the file lives under any of `dirs` (each given with a
    /// trailing `/`, e.g. `"engine/"`), at any nesting level.
    pub fn in_dirs(&self, dirs: &[&str]) -> bool {
        dirs.iter().any(|d| {
            self.path.starts_with(d) || self.path.contains(&format!("/{d}"))
        })
    }

    /// True when the file's basename is `name` inside directory prefix
    /// `dir` (e.g. `("engine/", "worker.rs")`).
    pub fn is_file(&self, dir: &str, name: &str) -> bool {
        let full = format!("{dir}{name}");
        self.path == full || self.path.ends_with(&format!("/{full}"))
    }
}

/// Lint one file's source text. `path` is the `/`-separated path
/// relative to the scan root (e.g. `engine/messages.rs`) — the rules'
/// scoping dispatches on it.
pub fn lint_source(path: &str, text: &str) -> Vec<Finding> {
    let file = SourceFile { path: path.replace('\\', "/"), scanned: scan::scan(text) };
    let mut raw = Vec::new();
    unordered_iter::check(&file, &mut raw);
    wall_clock::check(&file, &mut raw);
    step_pairing::check(&file, &mut raw);
    thread_confinement::check(&file, &mut raw);
    unwrap_hot_path::check(&file, &mut raw);
    stale_route::check(&file, &mut raw);

    // apply suppressions: a finding survives unless its line carries a
    // reasoned allow naming the rule
    let mut findings: Vec<Finding> = raw
        .into_iter()
        .filter(|f| {
            let allows = file
                .scanned
                .lines
                .get(f.line.wrapping_sub(1))
                .map(|l| l.allows.as_slice())
                .unwrap_or(&[]);
            !allows.iter().any(|a| a.reason_ok && a.name == f.rule.name())
        })
        .collect();

    // validate the annotations themselves (never suppressible)
    let known: Vec<&str> = RuleId::RULES.iter().map(|r| r.name()).collect();
    for line in &file.scanned.lines {
        for a in &line.allows {
            if !known.contains(&a.name.as_str()) {
                findings.push(Finding {
                    rule: RuleId::Annotation,
                    path: file.path.clone(),
                    line: a.line,
                    message: format!(
                        "allow({}) names no known rule (rules: {})",
                        a.name,
                        known.join(", ")
                    ),
                });
            } else if !a.reason_ok {
                findings.push(Finding {
                    rule: RuleId::Annotation,
                    path: file.path.clone(),
                    line: a.line,
                    message: format!(
                        "allow({}) has no reason — write `// detlint: allow({}) — <why>`",
                        a.name, a.name
                    ),
                });
            }
        }
    }

    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// Recursively collect the `.rs` files under `root` in sorted order.
fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<(String, std::path::PathBuf)>) -> std::io::Result<()> {
    let mut entries: Vec<_> =
        std::fs::read_dir(dir)?.collect::<Result<Vec<_>, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(root, &p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, p));
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (deterministic file order),
/// returning the surviving findings sorted by `(path, line, rule)`.
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs(root, root, &mut files)?;
    let mut findings = Vec::new();
    for (rel, path) in files {
        let text = std::fs::read_to_string(&path)?;
        findings.extend(lint_source(&rel, &text));
    }
    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
    Ok(findings)
}

/// Escape a string for inclusion in a JSON string literal.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render findings as the `--json` machine-readable report.
pub fn to_json(findings: &[Finding]) -> String {
    let mut s = String::from("{\"findings\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"rule\":\"{}\",\"path\":\"{}\",\"line\":{},\"message\":\"{}\"}}",
            f.rule,
            json_escape(&f.path),
            f.line,
            json_escape(&f.message)
        ));
    }
    s.push_str(&format!("],\"count\":{}}}", findings.len()));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn annotation_rule_flags_unknown_and_reasonless() {
        let src = "let a = 1; // detlint: allow(no-such-rule) — whatever\nlet b = 2; // detlint: allow(wall-clock)\n";
        let f = lint_source("engine/x.rs", src);
        assert_eq!(f.len(), 2);
        assert!(f.iter().all(|x| x.rule == RuleId::Annotation));
        assert_eq!(f[0].line, 1);
        assert_eq!(f[1].line, 2);
    }

    #[test]
    fn json_report_shape() {
        let f = vec![Finding {
            rule: RuleId::WallClock,
            path: "engine/x.rs".into(),
            line: 3,
            message: "a \"quoted\" message".into(),
        }];
        let j = to_json(&f);
        assert!(j.contains("\"rule\":\"wall-clock\""));
        assert!(j.contains("\"line\":3"));
        assert!(j.contains("\\\"quoted\\\""));
        assert!(j.ends_with("\"count\":1}"));
        assert_eq!(to_json(&[]), "{\"findings\":[],\"count\":0}");
    }
}
