//! R4 `thread-confinement` — thread creation lives in one file.
//!
//! The sequential/threaded equivalence argument is local to
//! `engine/worker.rs`: workers are shared-nothing within a superstep and
//! the barrier folds their outputs in partition order. A thread spawned
//! anywhere else has no such argument and silently widens the trusted
//! surface, so `thread::spawn` / `thread::scope` / `thread::Builder`
//! outside `engine/worker.rs` (tests exempt) is a violation.

use super::{Finding, RuleId, SourceFile};

const PATTERNS: [&str; 3] = ["thread::spawn", "thread::scope", "thread::Builder"];

pub(crate) fn check(file: &SourceFile, out: &mut Vec<Finding>) {
    if file.is_file("engine/", "worker.rs") {
        return;
    }
    for (idx, line) in file.scanned.lines.iter().enumerate() {
        if line.in_test || line.code.trim_start().starts_with("use ") {
            continue;
        }
        if let Some(p) = PATTERNS.iter().find(|p| line.code.contains(*p)) {
            out.push(Finding {
                rule: RuleId::ThreadConfinement,
                path: file.path.clone(),
                line: idx + 1,
                message: format!(
                    "{p} outside engine/worker.rs — thread creation is confined to \
                     the worker runtime, where the partition-order barrier makes \
                     parallelism deterministic"
                ),
            });
        }
    }
}
