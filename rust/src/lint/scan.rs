//! The line-wise tokenizer behind every `detlint` rule.
//!
//! Rules never look at raw source: [`scan`] first *blanks* everything
//! that is not code — `//` and nested `/* */` comments, string/byte
//! string literals (including multi-line ones) and char literals — so a
//! pattern like `HashMap` inside a doc comment or an assert message can
//! never trip a rule. While blanking it also extracts
//! `detlint: allow(<rule>) — <reason>` annotations from the comment
//! text, tracks `#[cfg(test)]`/`#[test]` regions by brace depth, and
//! records the brace depth at the start of every line for the rules
//! that need lexical structure (function pairing).

/// One inline suppression, parsed out of a comment.
#[derive(Clone, Debug)]
pub struct Allow {
    /// Rule name inside `allow(...)`.
    pub name: String,
    /// 1-based line the annotation was written on (not the line it
    /// applies to — a comment-line annotation applies to the next code
    /// line).
    pub line: usize,
    /// True when a non-empty reason follows the closing parenthesis.
    /// Reason-less allows are inert and reported as findings.
    pub reason_ok: bool,
}

/// One physical source line after blanking.
#[derive(Clone, Debug)]
pub struct Line {
    /// The line with comments and string/char literals replaced by
    /// spaces; braces, identifiers and punctuation survive verbatim.
    pub code: String,
    /// Brace depth at the start of the line.
    pub depth_start: usize,
    /// True inside a `#[cfg(test)]` / `#[test]` region (the attribute
    /// line, the braced body, and the closing brace line).
    pub in_test: bool,
    /// Suppressions applying to this line (same-line annotations plus
    /// any carried down from comment-only lines above).
    pub allows: Vec<Allow>,
}

/// A whole file, scanned.
#[derive(Debug, Default)]
pub struct Scanned {
    /// Blanked lines, index 0 = line 1.
    pub lines: Vec<Line>,
}

/// True for characters that can appear in a Rust identifier.
pub fn is_ident_char(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Byte offsets of `pat` in `code` where the preceding character is not
/// part of an identifier (so `HashMap` does not match `MyHashMapLike`'s
/// prefix; the *following* character is the caller's business since most
/// patterns end in punctuation).
pub fn find_unbound(code: &str, pat: &str) -> Vec<usize> {
    let mut hits = Vec::new();
    let bytes = code.as_bytes();
    let need_bound = pat.as_bytes().first().is_some_and(|&c| is_ident_char(c));
    let mut from = 0;
    while let Some(rel) = code[from..].find(pat) {
        let at = from + rel;
        let bounded = !need_bound || at == 0 || !is_ident_char(bytes[at - 1]);
        if bounded {
            hits.push(at);
        }
        from = at + pat.len().max(1);
    }
    hits
}

/// Lexer mode carried across lines.
enum Mode {
    Code,
    /// Inside `/* */`, with nesting depth.
    Block(u32),
    /// Inside a (possibly multi-line) string literal.
    Str,
}

/// Blank one line under the current mode. Returns the blanked code and
/// the comment text seen on this line (for annotation parsing).
fn blank_line(raw: &str, mode: &mut Mode) -> (String, String) {
    let b = raw.as_bytes();
    let mut code = String::with_capacity(raw.len());
    let mut comment = String::new();
    let mut i = 0;
    while i < b.len() {
        match mode {
            Mode::Block(depth) => {
                if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    *depth -= 1;
                    if *depth == 0 {
                        *mode = Mode::Code;
                    }
                    code.push_str("  ");
                    i += 2;
                } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    *depth += 1;
                    code.push_str("  ");
                    i += 2;
                } else {
                    comment.push(b[i] as char);
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::Str => {
                if b[i] == b'\\' {
                    code.push_str("  ");
                    i += 2; // skip the escaped character too
                } else if b[i] == b'"' {
                    *mode = Mode::Code;
                    code.push(' ');
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::Code => {
                if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
                    // line comment: the rest of the line is comment text
                    comment.push_str(&raw[i + 2..]);
                    for _ in i..b.len() {
                        code.push(' ');
                    }
                    i = b.len();
                } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    *mode = Mode::Block(1);
                    code.push_str("  ");
                    i += 2;
                } else if b[i] == b'"' {
                    *mode = Mode::Str;
                    code.push(' ');
                    i += 1;
                } else if b[i] == b'\'' {
                    // char literal vs lifetime: a backslash or a closing
                    // quote two characters ahead means char literal
                    if i + 1 < b.len() && b[i + 1] == b'\\' {
                        // escaped char literal: skip to the closing quote
                        let mut j = i + 3; // past '\x
                        while j < b.len() && b[j] != b'\'' {
                            j += 1;
                        }
                        let end = (j + 1).min(b.len());
                        for _ in i..end {
                            code.push(' ');
                        }
                        i = end;
                    } else if i + 2 < b.len() && b[i + 2] == b'\'' {
                        code.push_str("   ");
                        i += 3;
                    } else {
                        // lifetime: keep the tick, it breaks no rule
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(b[i] as char);
                    i += 1;
                }
            }
        }
    }
    (code, comment)
}

/// Parse every `detlint: allow(<rule>) <reason>` out of one line's
/// comment text. A "name" that is not plain kebab-case (e.g. the
/// `<rule>` placeholder this very sentence uses) is documentation, not
/// an annotation attempt, and is ignored.
fn parse_allows(comment: &str, line_no: usize) -> Vec<Allow> {
    const MARK: &str = "detlint: allow(";
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = comment[from..].find(MARK) {
        let name_start = from + rel + MARK.len();
        let Some(close_rel) = comment[name_start..].find(')') else {
            break;
        };
        let name = comment[name_start..name_start + close_rel].trim().to_string();
        if name.is_empty()
            || !name.bytes().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'-')
        {
            from = name_start + close_rel + 1;
            continue;
        }
        let rest = &comment[name_start + close_rel + 1..];
        // the reason is whatever follows, minus connective punctuation;
        // it must actually say something
        let reason = rest
            .trim_start_matches([' ', '\t', ':', '-', '—', '–'])
            .split("detlint: allow(")
            .next()
            .unwrap_or("")
            .trim();
        out.push(Allow { name, line: line_no, reason_ok: reason.len() >= 3 });
        from = name_start + close_rel + 1;
    }
    out
}

/// True when the blanked line carries a `#[cfg(test)]`-like or
/// `#[test]` attribute.
fn has_test_attr(code: &str) -> bool {
    code.contains("#[cfg(test)")
        || code.contains("#[cfg(any(test")
        || code.contains("#[cfg(all(test")
        || code.contains("#[test]")
}

/// Scan a whole file: blank every line, attach suppressions, and mark
/// test regions.
pub fn scan(text: &str) -> Scanned {
    let mut mode = Mode::Code;
    let mut lines = Vec::new();
    let mut pending_allows: Vec<Allow> = Vec::new();
    let mut depth = 0usize;
    // Some(d): inside a test region that closes when depth returns to d
    let mut test_close: Option<usize> = None;
    // a test attribute was seen and its item has not opened a brace yet
    let mut pending_attr = false;

    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let (code, comment) = blank_line(raw, &mut mode);
        let own_allows = parse_allows(&comment, line_no);
        let has_code = !code.trim().is_empty();

        if has_test_attr(&code) {
            pending_attr = true;
        }
        let mut in_test = test_close.is_some() || pending_attr;
        let depth_start = depth;
        let mut net = 0i64;
        for &c in code.as_bytes() {
            if c == b'{' {
                if pending_attr && test_close.is_none() {
                    test_close = Some(depth);
                    in_test = true;
                }
                pending_attr = false;
                depth += 1;
                net += 1;
            } else if c == b'}' {
                depth = depth.saturating_sub(1);
                net -= 1;
                if test_close == Some(depth) {
                    test_close = None;
                    in_test = true; // the closing-brace line is still test
                }
            }
        }
        // attribute on a braceless item (`#[cfg(test)] mod tests;`,
        // `#[cfg(test)] use ...;`): consumed by that single line
        if pending_attr && has_code && net == 0 && code.trim_end().ends_with(';') {
            pending_attr = false;
        }

        let allows = if has_code {
            let mut a = std::mem::take(&mut pending_allows);
            a.extend(own_allows);
            a
        } else {
            pending_allows.extend(own_allows);
            Vec::new()
        };
        lines.push(Line { code, depth_start, in_test, allows });
    }
    Scanned { lines }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked() {
        let s = scan("let x = \"HashMap\"; // HashMap here\nlet y = 1; /* HashMap */ let z = 2;\n");
        assert!(!s.lines[0].code.contains("HashMap"));
        assert!(s.lines[0].code.contains("let x ="));
        assert!(!s.lines[1].code.contains("HashMap"));
        assert!(s.lines[1].code.contains("let z = 2;"));
    }

    #[test]
    fn multi_line_block_comment_and_string() {
        let s = scan("/* a\nHashMap\n*/ let a = 1;\nlet s = \"x\ny\"; let b = 2;\n");
        assert!(!s.lines[1].code.contains("HashMap"));
        assert!(s.lines[2].code.contains("let a = 1;"));
        assert!(s.lines[3].code.contains("let b = 2;"));
        assert!(!s.lines[3].code.contains('y'));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let s = scan("s.push('{'); f::<'a>(x); let c = '\\n';\n");
        assert_eq!(s.lines[0].depth_start, 0, "brace inside char literal is not code");
        assert!(s.lines[0].code.contains("f::<'a>(x);"));
        let s2 = scan("if c == '{' {\n}\n");
        assert_eq!(s2.lines[1].depth_start, 1, "only the real brace counts");
    }

    #[test]
    fn doc_placeholder_is_not_an_annotation() {
        // documentation quoting the syntax must not register an allow
        let s = scan("// the syntax is `detlint: allow(<rule>) — <reason>`\nlet x = 1;\n");
        assert!(s.lines[1].allows.is_empty());
        let s2 = scan("let x = 1; // detlint: allow(WallClock) — wrong case\n");
        assert!(s2.lines[0].allows.is_empty());
    }

    #[test]
    fn allow_parses_name_and_requires_reason() {
        let s = scan("let x = 1; // detlint: allow(wall-clock) — reporting only\n");
        let a = &s.lines[0].allows[0];
        assert_eq!(a.name, "wall-clock");
        assert!(a.reason_ok);
        let s2 = scan("let x = 1; // detlint: allow(wall-clock)\n");
        assert!(!s2.lines[0].allows[0].reason_ok, "bare allow has no reason");
    }

    #[test]
    fn comment_line_allow_applies_to_next_code_line() {
        let s = scan("// detlint: allow(unordered-iter) — membership only\n// more prose\nlet m = 1;\n");
        assert!(s.lines[0].allows.is_empty());
        assert_eq!(s.lines[2].allows.len(), 1);
        assert_eq!(s.lines[2].allows[0].line, 1, "original annotation line preserved");
    }

    #[test]
    fn cfg_test_region_tracked_by_depth() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {\n        x();\n    }\n}\nfn live2() {}\n";
        let s = scan(src);
        assert!(!s.lines[0].in_test);
        assert!(s.lines[1].in_test, "attribute line");
        assert!(s.lines[4].in_test, "body");
        assert!(s.lines[6].in_test, "closing brace");
        assert!(!s.lines[7].in_test, "code after the region");
    }

    #[test]
    fn cfg_test_on_braceless_item_does_not_leak() {
        let s = scan("#[cfg(test)]\nuse foo::bar;\nfn live() {\n    x();\n}\n");
        assert!(s.lines[1].in_test);
        assert!(!s.lines[3].in_test, "region must not leak past the `;` item");
    }

    #[test]
    fn find_unbound_respects_identifier_boundaries() {
        assert_eq!(find_unbound("MyHashMap HashMap", "HashMap"), vec![10]);
        assert_eq!(find_unbound("x.iter() fruiter()", ".iter("), vec![1]);
    }
}
