//! R5 `unwrap-hot-path` — no panicking shortcuts in the hot path.
//!
//! `engine/worker.rs`, `engine/messages.rs` and `engine/state.rs` run
//! inside every sweep of every engine; a `.unwrap()`/`.expect(` there is
//! a latent abort on a path the tests may never drive. Invariants that
//! genuinely cannot fail are allowed, but must say so
//! (`allow(unwrap-hot-path)` + the argument) — and the debug sanitizers
//! (`engine/invariants.rs`) cross-check the arena/worklist invariants
//! those arguments rely on.

use super::{Finding, RuleId, SourceFile};

const HOT_FILES: [&str; 3] = ["worker.rs", "messages.rs", "state.rs"];

pub(crate) fn check(file: &SourceFile, out: &mut Vec<Finding>) {
    if !HOT_FILES.iter().any(|f| file.is_file("engine/", f)) {
        return;
    }
    for (idx, line) in file.scanned.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let pat = if line.code.contains(".unwrap()") {
            Some(".unwrap()")
        } else if line.code.contains(".expect(") {
            Some(".expect(")
        } else {
            None
        };
        if let Some(p) = pat {
            out.push(Finding {
                rule: RuleId::UnwrapHotPath,
                path: file.path.clone(),
                line: idx + 1,
                message: format!(
                    "{p} in a hot-path module — a sweep-path panic aborts the run; \
                     justify the invariant or handle the None/Err"
                ),
            });
        }
    }
}
