//! R3 `step-pairing` — every step opened is lexically closed.
//!
//! A `.begin_step(` / `.begin_step_into(` swaps a partition's inbox
//! pair and drains its frontier; until a `.commit_step(` or
//! `.abort_step_carryover(` closes the transaction, the runtime is
//! mid-step and a barrier would observe torn state (the exact livelock
//! PR 3's lifecycle refactor fixed). The contract is *lexical*: the
//! function that opens a step must contain a closer. The rule tracks
//! function frames by brace depth and fires at every opener in a frame
//! with zero closers.
//!
//! Scope: `engine/` and `partition/`. Runtime assertions already catch
//! dynamic misuse (`step_open`); this rule catches the paths tests never
//! execute.

use super::{Finding, RuleId, SourceFile};

const OPENER: &str = ".begin_step"; // prefix-matches .begin_step_into too
const CLOSERS: [&str; 2] = [".commit_step", ".abort_step_carryover"];

struct Frame {
    /// Brace depth *outside* the function body: the frame ends when a
    /// `}` returns the depth to this value.
    close_depth: usize,
    /// Lines of openers seen in this frame (not in inner frames).
    opens: Vec<usize>,
    closes: usize,
}

pub(crate) fn check(file: &SourceFile, out: &mut Vec<Finding>) {
    if !file.in_dirs(&["engine/", "partition/"]) {
        return;
    }
    let mut depth = 0usize;
    let mut frames: Vec<Frame> = Vec::new();
    // between a `fn` keyword and its body brace; cancelled by `;`/`,` at
    // signature top level (trait method declarations, fn-pointer types)
    let mut pending_fn = false;
    let mut sig_nest = 0i64;

    let mut finalize = |f: Frame, out: &mut Vec<Finding>| {
        if !f.opens.is_empty() && f.closes == 0 {
            for line in f.opens {
                out.push(Finding {
                    rule: RuleId::StepPairing,
                    path: file.path.clone(),
                    line,
                    message: "begin_step with no commit_step/abort_step_carryover \
                              in the same function — the step transaction leaks \
                              past the function that opened it"
                        .into(),
                });
            }
        }
    };

    for (idx, line) in file.scanned.lines.iter().enumerate() {
        let code = line.code.as_bytes();
        let text = &line.code;
        let mut i = 0;
        while i < code.len() {
            let b = code[i];
            if b == b'{' {
                if pending_fn {
                    frames.push(Frame { close_depth: depth, opens: Vec::new(), closes: 0 });
                    pending_fn = false;
                }
                depth += 1;
                i += 1;
            } else if b == b'}' {
                depth = depth.saturating_sub(1);
                if frames.last().is_some_and(|f| f.close_depth == depth) {
                    if let Some(f) = frames.pop() {
                        finalize(f, out);
                    }
                }
                i += 1;
            } else if pending_fn && (b == b'(' || b == b'[' || b == b'<') {
                sig_nest += 1;
                i += 1;
            } else if pending_fn && (b == b')' || b == b']') {
                sig_nest -= 1;
                i += 1;
            } else if pending_fn && b == b'>' {
                // not the arrow's `>`
                if i == 0 || code[i - 1] != b'-' {
                    sig_nest -= 1;
                }
                i += 1;
            } else if pending_fn && (b == b';' || b == b',') && sig_nest <= 0 {
                // braceless declaration or fn-pointer type: no body
                pending_fn = false;
                i += 1;
            } else if text[i..].starts_with("fn")
                && (i == 0 || !super::scan::is_ident_char(code[i - 1]))
                && !code.get(i + 2).is_some_and(|&c| super::scan::is_ident_char(c))
            {
                pending_fn = true;
                sig_nest = 0;
                i += 2;
            } else if !line.in_test && text[i..].starts_with(OPENER) {
                if let Some(f) = frames.last_mut() {
                    f.opens.push(idx + 1);
                }
                i += OPENER.len();
            } else if !line.in_test && CLOSERS.iter().any(|c| text[i..].starts_with(c)) {
                if let Some(f) = frames.last_mut() {
                    f.closes += 1;
                }
                i += 1;
            } else {
                i += 1;
            }
        }
    }
    // unterminated frames at EOF (truncated fixtures) still report
    while let Some(f) = frames.pop() {
        finalize(f, out);
    }
}
