//! R6 `stale-route` — routing state must not be cached across a step
//! commit.
//!
//! Since the routing-epoch refactor, every location-table entry,
//! `EdgeRoute`, and route column is *epoch-scoped*: the online
//! repartitioner may migrate vertices at the barrier that follows a
//! `.commit_step`, rewriting `(partition, local)` coordinates and
//! invalidating anything resolved under the old epoch. A binding like
//!
//! ```text
//! let (tp, tl) = dg.routing.location[v as usize];
//! ...
//! rt.commit_step();            // barrier may migrate v here
//! send(tp, tl, msg);           // stale — v may live elsewhere now
//! ```
//!
//! is the exact bug class the epoch versioning exists to prevent. The
//! rule fires on any `let` that binds route/location data lexically
//! before a `.commit_step` in the same function frame (the conservative
//! lexical analogue of "cached across the boundary" — re-read the
//! table after the commit instead, or move the binding below it).
//!
//! Scope: `engine/` and `partition/`. `engine/worker.rs` is exempt —
//! the sweep core *is* the sanctioned reader of route columns, and its
//! bindings die with the sweep that owns them, strictly before the
//! commit takes effect at the barrier.

use super::scan::find_unbound;
use super::{Finding, RuleId, SourceFile};

const COMMIT: &str = ".commit_step";
/// Identifier tokens (matched identifier-bounded on the left).
const IDENT_TOKENS: [&str; 2] = ["EdgeRoute", "route_iter"];
/// Field/method access tokens (matched as plain substrings).
const ACCESS_TOKENS: [&str; 5] = [".location[", ".location.", ".routes[", ".routes.", ".route("];

struct Frame {
    /// Brace depth *outside* the function body: the frame ends when a
    /// `}` returns the depth to this value.
    close_depth: usize,
    /// `let`-with-route-token lines seen in this frame that no commit
    /// has flagged yet.
    route_lets: Vec<usize>,
}

/// Does this (comment/string-scrubbed) line bind route or location data?
fn binds_route_data(code: &str) -> bool {
    let bytes = code.as_bytes();
    let has_let = find_unbound(code, "let")
        .iter()
        .any(|&at| !bytes.get(at + 3).is_some_and(|&c| super::scan::is_ident_char(c)));
    if !has_let {
        return false;
    }
    IDENT_TOKENS.iter().any(|t| !find_unbound(code, t).is_empty())
        || ACCESS_TOKENS.iter().any(|t| code.contains(t))
}

pub(crate) fn check(file: &SourceFile, out: &mut Vec<Finding>) {
    if !file.in_dirs(&["engine/", "partition/"]) || file.is_file("engine/", "worker.rs") {
        return;
    }
    let mut depth = 0usize;
    let mut frames: Vec<Frame> = Vec::new();
    // between a `fn` keyword and its body brace; cancelled by `;`/`,` at
    // signature top level (trait method declarations, fn-pointer types)
    let mut pending_fn = false;
    let mut sig_nest = 0i64;

    for (idx, line) in file.scanned.lines.iter().enumerate() {
        let code = line.code.as_bytes();
        let text = &line.code;
        if !line.in_test && binds_route_data(text) {
            if let Some(f) = frames.last_mut() {
                f.route_lets.push(idx + 1);
            }
        }
        let mut i = 0;
        while i < code.len() {
            let b = code[i];
            if b == b'{' {
                if pending_fn {
                    frames.push(Frame { close_depth: depth, route_lets: Vec::new() });
                    pending_fn = false;
                }
                depth += 1;
                i += 1;
            } else if b == b'}' {
                depth = depth.saturating_sub(1);
                if frames.last().is_some_and(|f| f.close_depth == depth) {
                    frames.pop();
                }
                i += 1;
            } else if pending_fn && (b == b'(' || b == b'[' || b == b'<') {
                sig_nest += 1;
                i += 1;
            } else if pending_fn && (b == b')' || b == b']') {
                sig_nest -= 1;
                i += 1;
            } else if pending_fn && b == b'>' {
                // not the arrow's `>`
                if i == 0 || code[i - 1] != b'-' {
                    sig_nest -= 1;
                }
                i += 1;
            } else if pending_fn && (b == b';' || b == b',') && sig_nest <= 0 {
                // braceless declaration or fn-pointer type: no body
                pending_fn = false;
                i += 1;
            } else if text[i..].starts_with("fn")
                && (i == 0 || !super::scan::is_ident_char(code[i - 1]))
                && !code.get(i + 2).is_some_and(|&c| super::scan::is_ident_char(c))
            {
                pending_fn = true;
                sig_nest = 0;
                i += 2;
            } else if !line.in_test && text[i..].starts_with(COMMIT) {
                if let Some(f) = frames.last_mut() {
                    for l in f.route_lets.drain(..) {
                        out.push(Finding {
                            rule: RuleId::StaleRoute,
                            path: file.path.clone(),
                            line: l,
                            message: "route/location data bound before a .commit_step in \
                                      the same function — routing state is epoch-scoped \
                                      and the barrier may migrate vertices; re-read it \
                                      from the post-commit RoutingEpoch instead"
                                .into(),
                        });
                    }
                }
                i += COMMIT.len();
            } else {
                i += 1;
            }
        }
    }
}
