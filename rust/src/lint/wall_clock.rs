//! R2 `wall-clock` — wall-clock reads only at annotated reporting sites.
//!
//! The engines' results and scheduling decisions must be functions of
//! the graph and the program alone; real time may only be *measured*
//! for telemetry (the per-engine `compute_us` probes, the
//! [`crate::util::Stopwatch`]). Any `Instant::now` / `SystemTime` read
//! therefore needs an `allow(wall-clock)` stating it is reporting-only.
//!
//! Scope: everything except `runtime/` — the XLA/PJRT accelerator layer
//! is feature-gated off the deterministic comparison path and times
//! device execution.

use super::scan::find_unbound;
use super::{Finding, RuleId, SourceFile};

pub(crate) fn check(file: &SourceFile, out: &mut Vec<Finding>) {
    if file.in_dirs(&["runtime/"]) {
        return;
    }
    for (idx, line) in file.scanned.lines.iter().enumerate() {
        if line.in_test || line.code.trim_start().starts_with("use ") {
            continue;
        }
        let pat = if !find_unbound(&line.code, "Instant::now").is_empty() {
            Some("Instant::now")
        } else if !find_unbound(&line.code, "SystemTime").is_empty() {
            Some("SystemTime")
        } else {
            None
        };
        if let Some(p) = pat {
            out.push(Finding {
                rule: RuleId::WallClock,
                path: file.path.clone(),
                line: idx + 1,
                message: format!(
                    "{p} read — wall clocks must stay reporting-only; results and \
                     scheduling may not depend on real time"
                ),
            });
        }
    }
}
