//! R1 `unordered-iter` — no unordered hash containers in the
//! deterministic core.
//!
//! Scope: `engine/` and `partition/`. Two kinds of sites fire:
//!
//! - **declarations** of `HashMap`/`HashSet` (and the `FxHashMap`/
//!   `FxHashSet` variants): any type mention or constructor. A
//!   membership-only container is legitimate (`Outbox::latest`) but must
//!   say so in an `allow` — hash order silently reaching an output is
//!   exactly the bug class PR 3 fixed.
//! - **iteration** over a container declared in the same file:
//!   `.iter()`, `.iter_mut()`, `.into_iter()`, `.keys()`, `.values()`,
//!   `.values_mut()`, `.drain(`, `.retain(`, and `for ... in <name>`.
//!   Iteration is flagged even when the declaration carries an allow —
//!   the declaration's rationale ("membership only") does not extend to
//!   iterating it.

use super::scan::{find_unbound, is_ident_char};
use super::{Finding, RuleId, SourceFile};

const CONTAINERS: [&str; 4] = ["HashMap", "HashSet", "FxHashMap", "FxHashSet"];
const ITER_METHODS: [&str; 8] = [
    ".iter()",
    ".iter_mut()",
    ".into_iter()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".retain(",
];

/// Extract the name a declaration line binds: `let [mut] NAME` or a
/// struct-field / parameter `NAME:` at the start of the trimmed line.
fn bound_name(code: &str) -> Option<String> {
    let t = code.trim_start();
    if let Some(rest) = t.strip_prefix("let ") {
        let rest = rest.strip_prefix("mut ").unwrap_or(rest);
        let name: String =
            rest.bytes().take_while(|&c| is_ident_char(c)).map(char::from).collect();
        return (!name.is_empty()).then_some(name);
    }
    let t = t.strip_prefix("pub(crate) ").unwrap_or(t);
    let t = t.strip_prefix("pub ").unwrap_or(t);
    let name: String =
        t.bytes().take_while(|&c| is_ident_char(c)).map(char::from).collect();
    if !name.is_empty() && t[name.len()..].starts_with(':') && !t[name.len()..].starts_with("::") {
        return Some(name);
    }
    None
}

/// The identifier iterated by a `for ... in <expr>` line, if the
/// expression is a plain (possibly `self.`-qualified, possibly borrowed)
/// name.
fn for_loop_target(code: &str) -> Option<String> {
    let for_at = find_unbound(code, "for ").into_iter().next()?;
    let in_at = code[for_at..].find(" in ")? + for_at + 4;
    let mut expr = code[in_at..].trim_start();
    for p in ["&mut ", "&", "*"] {
        expr = expr.strip_prefix(p).unwrap_or(expr);
    }
    expr = expr.strip_prefix("self.").unwrap_or(expr);
    let name: String =
        expr.bytes().take_while(|&c| is_ident_char(c)).map(char::from).collect();
    // only a bare name (optionally followed by the loop body brace):
    // `for x in map.values()` is caught by the method patterns instead
    let rest = expr[name.len()..].trim_start();
    (!name.is_empty() && (rest.is_empty() || rest.starts_with('{'))).then_some(name)
}

pub(crate) fn check(file: &SourceFile, out: &mut Vec<Finding>) {
    if !file.in_dirs(&["engine/", "partition/"]) {
        return;
    }
    let mut names: Vec<String> = Vec::new();
    for (idx, line) in file.scanned.lines.iter().enumerate() {
        if line.in_test || line.code.trim_start().starts_with("use ") {
            continue;
        }
        let code = &line.code;
        let mentioned = CONTAINERS
            .iter()
            .find(|c| find_unbound(code, c).iter().any(|&at| {
                // a genuine container reference: `HashMap<`, `HashMap::`
                let after = &code[at + c.len()..];
                after.starts_with('<') || after.starts_with("::")
            }));
        if let Some(c) = mentioned {
            if let Some(n) = bound_name(code) {
                if !names.contains(&n) {
                    names.push(n);
                }
            }
            out.push(Finding {
                rule: RuleId::UnorderedIter,
                path: file.path.clone(),
                line: idx + 1,
                message: format!(
                    "{c} in a deterministic module — iteration order is \
                     hasher-dependent; if membership/lookup-only, annotate why"
                ),
            });
        }
        // iteration over a tracked container
        for n in &names {
            let method_hit = ITER_METHODS
                .iter()
                .any(|m| !find_unbound(code, &format!("{n}{m}")).is_empty());
            let for_hit = for_loop_target(code).as_deref() == Some(n.as_str());
            if method_hit || for_hit {
                out.push(Finding {
                    rule: RuleId::UnorderedIter,
                    path: file.path.clone(),
                    line: idx + 1,
                    message: format!(
                        "iteration over unordered container `{n}` — order depends \
                         on the hasher and breaks sequential/threaded equivalence"
                    ),
                });
            }
        }
    }
}
