"""AOT compile path: lower the L2 local-phase programs to HLO **text**.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and
NOT a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run from ``python/``:  python -m compile.aot --outdir ../artifacts
(the Makefile drives this; it is a no-op for unchanged inputs via make).

Artifacts (block size N, scan length K fixed at AOT time):
  pagerank_local.hlo.txt  (m:(N,N), rank:(N,1), delta:(N,1))
                          -> (rank', delta', acc, linf)
  sssp_local.hlo.txt      (w:(N,N), d:(N,1)) -> (d', changed)
  manifest.txt            one line per artifact: name n steps inputs outputs
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.minplus import blocked_minplus_matvec
from .kernels.pagerank_block import blocked_matvec

# AOT parameters. N is the densified-partition tile edge; K the number of
# pseudo-supersteps fused into one executable invocation. Rust pads
# partitions to N and re-invokes in K-step chunks until convergence.
AOT_N = 256
AOT_STEPS = 8
AOT_BLOCK = 128


def pagerank_local_phase_aot(m, rank, delta):
    """Non-donating clone of model.pagerank_local_phase for lowering.

    (Donated buffers add input_output_alias annotations to the HLO that
    buy nothing through the text interchange; keep the artifact plain.)
    """

    def step(carry, _):
        rank, delta, acc = carry
        acc = acc + delta
        new_delta = blocked_matvec(m, delta, block=AOT_BLOCK)
        return (rank + new_delta, new_delta, acc), None

    init = (rank, delta, jnp.zeros_like(delta))
    (rank, delta, acc), _ = jax.lax.scan(step, init, None, length=AOT_STEPS)
    linf = jnp.max(jnp.abs(delta))
    return rank, delta, acc, linf


def sssp_local_phase_aot(w, d):
    def step(d, _):
        return jnp.minimum(d, blocked_minplus_matvec(w, d, block=AOT_BLOCK)), None

    d0 = d
    d, _ = jax.lax.scan(step, d, None, length=AOT_STEPS)
    changed = jnp.sum((d < d0).astype(jnp.int32))
    return d, changed


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat: ignored, use --outdir")
    args = ap.parse_args()
    outdir = args.outdir
    if args.out is not None:
        outdir = os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)

    mat = jax.ShapeDtypeStruct((AOT_N, AOT_N), jnp.float32)
    vec = jax.ShapeDtypeStruct((AOT_N, 1), jnp.float32)

    manifest = []

    lowered = jax.jit(pagerank_local_phase_aot).lower(mat, vec, vec)
    text = to_hlo_text(lowered)
    path = os.path.join(outdir, "pagerank_local.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest.append(f"pagerank_local {AOT_N} {AOT_STEPS} m,rank,delta rank,delta,acc,linf")
    print(f"wrote {path} ({len(text)} chars)")

    lowered = jax.jit(sssp_local_phase_aot).lower(mat, vec)
    text = to_hlo_text(lowered)
    path = os.path.join(outdir, "sssp_local.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest.append(f"sssp_local {AOT_N} {AOT_STEPS} w,d d,changed")
    print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(outdir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
