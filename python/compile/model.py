"""L2: the GraphHP *local phase* as a JAX program.

A GraphHP local phase is a partition-private fixed-point iteration
(pseudo-supersteps) with no cross-partition synchronization. For
value-propagation algorithms this is a scan over the L1 kernel step:

- incremental PageRank (paper Alg. 5): delta-propagation mat-vec per step;
- SSSP (paper Alg. 4): min-plus relaxation per step.

``lax.scan`` fuses the whole phase into a single HLO while-loop, so the
Rust coordinator launches ONE executable per local phase (per K-step
chunk), not one dispatch per pseudo-superstep — the on-chip analogue of
the paper's "pseudo-superstep iteration is performed entirely in memory".

Every function here is shape-polymorphic in python but is AOT-lowered by
``aot.py`` at fixed (n, K) to HLO text the Rust runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.minplus import blocked_minplus_matvec
from .kernels.pagerank_block import blocked_matvec

DEFAULT_STEPS = 8


@functools.partial(jax.jit, static_argnames=("steps", "block"), donate_argnums=(1, 2))
def pagerank_local_phase(m, rank, delta, steps: int = DEFAULT_STEPS, block: int = 128):
    """Run ``steps`` PageRank pseudo-supersteps on one densified partition.

    Args:
      m:     (n, n) f32 — damped column-normalized transpose internal
             adjacency of the partition (``M[i,j] = d*A[j,i]/outdeg(j)``).
      rank:  (n, 1) f32 — current PageRank values.
      delta: (n, 1) f32 — pending (undelivered) rank updates.
      steps: pseudo-supersteps per invocation; the coordinator re-invokes
             while ``linf`` exceeds the tolerance.

    Returns:
      (rank', delta', acc, linf): new state, the summed per-step input
      deltas (for remote-message derivation), and the final ||delta'||_inf
      so the coordinator can test convergence without touching the vector.
    """

    def step(carry, _):
        rank, delta, acc = carry
        acc = acc + delta
        new_delta = blocked_matvec(m, delta, block=block)
        return (rank + new_delta, new_delta, acc), None

    init = (rank, delta, jnp.zeros_like(delta))
    (rank, delta, acc), _ = jax.lax.scan(step, init, None, length=steps)
    linf = jnp.max(jnp.abs(delta))
    return rank, delta, acc, linf


@functools.partial(jax.jit, static_argnames=("steps", "block"), donate_argnums=(1,))
def sssp_local_phase(w, d, steps: int = DEFAULT_STEPS, block: int = 128):
    """Run ``steps`` SSSP relaxation pseudo-supersteps on one partition.

    Args:
      w: (n, n) f32 — internal edge weights, ``INF`` where no edge.
      d: (n, 1) f32 — current tentative distances.

    Returns:
      (d', changed): relaxed distances and a scalar count of vertices whose
      distance improved this invocation (0 => the partition quiesced).
    """

    def step(d, _):
        nd = jnp.minimum(d, blocked_minplus_matvec(w, d, block=block))
        return nd, None

    d0 = d
    d, _ = jax.lax.scan(step, d, None, length=steps)
    changed = jnp.sum((d < d0).astype(jnp.int32))
    return d, changed
