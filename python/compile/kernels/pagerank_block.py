"""L1 Pallas kernel: blocked dense mat-vec for the incremental-PageRank
local phase (GraphHP pseudo-superstep).

One GraphHP pseudo-superstep of the accumulative PageRank algorithm
(paper Alg. 5) over a partition's *internal* adjacency is

    delta_out = M @ delta_in        # M[i,j] = d * A[j,i] / outdeg(j)
    rank_out  = rank_in + delta_out

where ``M`` is the damped, column-normalized transpose adjacency of the
partition, densified into a tile by the Rust coordinator
(``runtime/accel.rs``).

The kernel is written as a VMEM-tiled blocked mat-vec: the grid walks
(row-block, col-block); each step multiplies a ``(BR, BC)`` tile of ``M``
against a ``(BC, 1)`` slice of the delta vector, accumulating partial sums
in the output block, which Pallas keeps resident in VMEM across the inner
(column) grid dimension. This is the HBM->VMEM schedule a GPU
implementation would express with threadblocks + shared memory; BlockSpec
expresses it here (see DESIGN.md §6 Hardware adaptation).

interpret=True is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shape: 128 matches the MXU systolic-array edge; a
# (128, 128) f32 tile is 64 KiB, so tile + vector slices + output block
# stay well under 1 MiB of VMEM even double-buffered (DESIGN.md §7).
DEFAULT_BLOCK = 128


def _matvec_kernel(m_ref, x_ref, o_ref):
    """One grid step: o[br] (+)= M[br, bc] @ x[bc]."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        m_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


def blocked_matvec(m: jax.Array, x: jax.Array, block: int = DEFAULT_BLOCK) -> jax.Array:
    """``m @ x`` with ``m: (n, n) f32`` and ``x: (n, 1) f32`` via Pallas.

    ``n`` must be a multiple of ``block``; the Rust side pads partitions to
    the AOT block size.
    """
    n = m.shape[0]
    if m.shape != (n, n) or x.shape != (n, 1):
        raise ValueError(f"bad shapes m={m.shape} x={x.shape}")
    if n % block != 0:
        raise ValueError(f"n={n} not a multiple of block={block}")
    grid = (n // block, n // block)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),  # M tile
            pl.BlockSpec((block, 1), lambda i, j: (j, 0)),  # delta slice
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=True,
    )(m, x)


@functools.partial(jax.jit, static_argnames=("block",))
def pagerank_step(
    m: jax.Array, rank: jax.Array, delta: jax.Array, block: int = DEFAULT_BLOCK
):
    """One pseudo-superstep: returns ``(rank + M@delta, M@delta)``."""
    new_delta = blocked_matvec(m, delta, block=block)
    return rank + new_delta, new_delta
