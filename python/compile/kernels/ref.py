"""Pure-jnp correctness oracles for the Pallas kernels.

These are the semantics the kernels must match bit-for-bit structurally
(allclose numerically): a plain dense mat-vec for the PageRank delta
propagation and a broadcast min-plus product for SSSP relaxation.
"""

from __future__ import annotations

import jax.numpy as jnp


def matvec_ref(m, x):
    """``m @ x`` for m:(n,n), x:(n,1)."""
    return m @ x


def pagerank_step_ref(m, rank, delta):
    """One accumulative-PageRank pseudo-superstep (paper Alg. 5)."""
    new_delta = m @ delta
    return rank + new_delta, new_delta


def minplus_matvec_ref(w, x):
    """Min-plus product: out[i] = min_j (w[i,j] + x[j,0]); shape (n,1)."""
    return jnp.min(w + x.reshape(1, -1), axis=1, keepdims=True)


def sssp_step_ref(w, d):
    """One SSSP relaxation: d' = min(d, W (+) d)."""
    return jnp.minimum(d, minplus_matvec_ref(w, d))


def pagerank_local_phase_ref(m, rank, delta, steps):
    """K pseudo-supersteps by plain python loop (oracle for the scan model).

    Returns (rank, delta, acc) where acc accumulates the deltas *fed into*
    each step — the quantity the coordinator uses to derive the messages a
    partition owes its remote neighbors at the next global barrier.
    """
    acc = jnp.zeros_like(delta)
    for _ in range(steps):
        acc = acc + delta
        new_delta = m @ delta
        rank = rank + new_delta
        delta = new_delta
    return rank, delta, acc


def sssp_local_phase_ref(w, d, steps):
    """K relaxation sweeps by plain python loop."""
    for _ in range(steps):
        d = jnp.minimum(d, minplus_matvec_ref(w, d))
    return d
