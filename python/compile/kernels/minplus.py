"""L1 Pallas kernel: blocked min-plus mat-vec for the SSSP local phase.

One GraphHP pseudo-superstep of single-source shortest paths (paper
Alg. 4) over a partition's internal adjacency is one Bellman-Ford
relaxation sweep, i.e. a mat-vec over the (min, +) semiring:

    cand[i] = min_j ( W[i, j] + d[j] )        # W[i,j] = w(j -> i), +inf if no edge
    d'[i]   = min(d[i], cand[i])              # the outer min happens in L2

The (min,+) product cannot use the MXU (it is not a ring matmul), so the
kernel targets the VPU: each grid step loads a ``(BR, BC)`` tile of W and a
``(BC,)`` slice of d into VMEM, forms the broadcast sum, and reduces with a
lane-wise min, accumulating the running block minimum in the VMEM-resident
output block across the column grid dimension.

Padding convention: absent edges and padding rows/cols hold ``INF``
(a large finite f32 — using actual ``inf`` would generate nan via
inf + -inf in user-supplied corner cases; Rust uses the same constant).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128

# "Infinity" for distances. Finite so that INF + INF does not overflow f32
# (3.4e38); 1e30 + 1e30 = 2e30 stays representable and still compares
# larger than any feasible path length. A plain python float: a jnp scalar
# would be captured as a constant by the Pallas kernel, which pallas_call
# rejects.
INF = 1e30


def _minplus_kernel(w_ref, x_ref, o_ref):
    """One grid step: o[br] = min(o[br], min_j(W[br, bc] + x[bc]))."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, INF)

    # (BR, BC) + (1, BC) -> (BR, BC), reduce min over columns -> (BR, 1)
    cand = jnp.min(w_ref[...] + x_ref[...].reshape(1, -1), axis=1, keepdims=True)
    o_ref[...] = jnp.minimum(o_ref[...], cand)


def blocked_minplus_matvec(
    w: jax.Array, x: jax.Array, block: int = DEFAULT_BLOCK
) -> jax.Array:
    """Min-plus product ``(W (+) x)[i] = min_j W[i,j] + x[j]``.

    ``w: (n, n) f32`` (INF for absent edges), ``x: (n, 1) f32``.
    """
    n = w.shape[0]
    if w.shape != (n, n) or x.shape != (n, 1):
        raise ValueError(f"bad shapes w={w.shape} x={x.shape}")
    if n % block != 0:
        raise ValueError(f"n={n} not a multiple of block={block}")
    grid = (n // block, n // block)
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),  # W tile
            pl.BlockSpec((block, 1), lambda i, j: (j, 0)),  # distance slice
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=True,
    )(w, x)


@functools.partial(jax.jit, static_argnames=("block",))
def sssp_step(w: jax.Array, d: jax.Array, block: int = DEFAULT_BLOCK) -> jax.Array:
    """One relaxation pseudo-superstep: ``d' = min(d, W (+) d)``."""
    return jnp.minimum(d, blocked_minplus_matvec(w, d, block=block))
