"""AOT artifact sanity: the lowered HLO text parses, mentions the right
entry computation shape, and the AOT clones match the donating L2 models.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import aot
from compile.kernels import ref
from compile.kernels.minplus import INF


def test_pagerank_aot_clone_matches_ref():
    n = aot.AOT_N
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.uniform(0, 0.01, (n, n)).astype(np.float32))
    rank = jnp.asarray(rng.uniform(0, 1, (n, 1)).astype(np.float32))
    delta = jnp.asarray(rng.uniform(0, 1, (n, 1)).astype(np.float32))
    got_r, got_d, got_acc, got_linf = aot.pagerank_local_phase_aot(m, rank, delta)
    want_r, want_d, want_acc = ref.pagerank_local_phase_ref(m, rank, delta, aot.AOT_STEPS)
    assert_allclose(np.asarray(got_r), np.asarray(want_r), rtol=1e-4, atol=1e-5)
    assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-4, atol=1e-5)
    assert_allclose(np.asarray(got_acc), np.asarray(want_acc), rtol=1e-4, atol=1e-5)


def test_sssp_aot_clone_matches_ref():
    n = aot.AOT_N
    rng = np.random.default_rng(1)
    w = np.full((n, n), float(INF), np.float32)
    mask = rng.uniform(size=(n, n)) < 0.05
    w[mask] = rng.uniform(0.1, 10.0, size=mask.sum()).astype(np.float32)
    d = np.full((n, 1), float(INF), np.float32)
    d[0, 0] = 0.0
    got_d, changed = aot.sssp_local_phase_aot(jnp.asarray(w), jnp.asarray(d))
    want = ref.sssp_local_phase_ref(jnp.asarray(w), jnp.asarray(d), aot.AOT_STEPS)
    assert_allclose(np.asarray(got_d), np.asarray(want), rtol=1e-6)
    assert int(changed) > 0


def test_hlo_text_lowering_roundtrip_shape():
    mat = jax.ShapeDtypeStruct((aot.AOT_N, aot.AOT_N), jnp.float32)
    vec = jax.ShapeDtypeStruct((aot.AOT_N, 1), jnp.float32)
    lowered = jax.jit(aot.pagerank_local_phase_aot).lower(mat, vec, vec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert f"f32[{aot.AOT_N},{aot.AOT_N}]" in text
    # return_tuple=True => the ROOT is a tuple of the four outputs
    assert "ENTRY" in text


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(out)],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    for name in ("pagerank_local.hlo.txt", "sssp_local.hlo.txt", "manifest.txt"):
        p = out / name
        assert p.exists() and p.stat().st_size > 0
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 2
    assert manifest[0].startswith("pagerank_local 256 8")
    assert manifest[1].startswith("sssp_local 256 8")
