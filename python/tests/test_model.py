"""L2 correctness: scan-fused local phases vs step-composition oracles,
plus convergence semantics on real (small) graph structures.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.minplus import INF
from compile.model import pagerank_local_phase, sssp_local_phase

hypothesis.settings.register_profile(
    "model", deadline=None, max_examples=15, derandomize=True
)
hypothesis.settings.load_profile("model")


def pagerank_matrix(seed, n, damping=0.85):
    """Damped column-normalized transpose adjacency of a random digraph."""
    r = np.random.default_rng(seed)
    a = (r.uniform(size=(n, n)) < 0.2).astype(np.float32)
    np.fill_diagonal(a, 0.0)
    outdeg = a.sum(axis=1, keepdims=True)
    p = np.divide(a, outdeg, out=np.zeros_like(a), where=outdeg > 0)
    return jnp.asarray(damping * p.T)


def sparse_weights(seed, n, density=0.25):
    r = np.random.default_rng(seed)
    w = np.full((n, n), float(INF), np.float32)
    mask = r.uniform(size=(n, n)) < density
    w[mask] = r.uniform(0.1, 10.0, size=mask.sum()).astype(np.float32)
    return jnp.asarray(w)


@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    steps=st.sampled_from([1, 3, 8]),
)
def test_pagerank_local_phase_matches_loop(seed, steps):
    n = 64
    m = pagerank_matrix(seed, n)
    rng = np.random.default_rng(seed)
    rank = jnp.asarray(rng.uniform(0, 1, (n, 1)).astype(np.float32))
    delta = jnp.asarray(rng.uniform(0, 0.5, (n, 1)).astype(np.float32))
    # compute the oracle FIRST: the model donates rank/delta buffers
    want_r, want_d, want_acc = ref.pagerank_local_phase_ref(m, rank, delta, steps)
    got_r, got_d, got_acc, got_linf = pagerank_local_phase(m, rank, delta, steps=steps, block=16)
    assert_allclose(np.asarray(got_r), np.asarray(want_r), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(got_acc), np.asarray(want_acc), rtol=1e-5, atol=1e-6)
    assert_allclose(float(got_linf), float(np.abs(np.asarray(want_d)).max()), rtol=1e-5, atol=1e-7)


def test_pagerank_local_phase_converges_to_power_iteration():
    # Iterating the local phase drains the deltas: rank approaches the
    # damped PageRank solve rank = r0 + M rank-ish fixed point.
    n, damping = 32, 0.85
    m = pagerank_matrix(7, n, damping)
    rank = jnp.full((n, 1), 0.15, jnp.float32)
    delta = jnp.full((n, 1), 0.15, jnp.float32)
    for _ in range(40):
        rank, delta, _, linf = pagerank_local_phase(m, rank, delta, steps=8, block=16)
        if float(linf) < 1e-9:
            break
    # closed form: rank = (I - M)^-1 r0 with r0 = 0.15 (+ the initial 0.15
    # already counted in rank but whose propagation is delta's job)
    m_np = np.asarray(m, np.float64)
    want = np.linalg.solve(np.eye(n) - m_np, np.full((n, 1), 0.15))
    assert_allclose(np.asarray(rank, np.float64), want, rtol=1e-4, atol=1e-5)


@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    steps=st.sampled_from([1, 4, 8]),
)
def test_sssp_local_phase_matches_loop(seed, steps):
    n = 64
    w = sparse_weights(seed, n)
    rng = np.random.default_rng(seed + 3)
    d = np.full((n, 1), float(INF), np.float32)
    d[rng.integers(0, n), 0] = 0.0
    d = jnp.asarray(d)
    d_np = np.asarray(d)
    want = ref.sssp_local_phase_ref(w, d, steps)  # before donation
    got, changed = sssp_local_phase(w, d, steps=steps, block=16)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    assert int(changed) == int((np.asarray(want) < d_np).sum())


def test_sssp_local_phase_reaches_bellman_ford_fixpoint():
    n = 48
    w = sparse_weights(9, n, density=0.15)
    d = np.full((n, 1), float(INF), np.float32)
    d[0, 0] = 0.0
    d = jnp.asarray(d)
    wn = np.asarray(w, np.float64)
    # iterate until quiesced
    for _ in range(20):
        d, changed = sssp_local_phase(w, d, steps=8, block=16)
        if int(changed) == 0:
            break
    assert int(changed) == 0
    # oracle: scipy-free Bellman-Ford on numpy
    dist = np.full(n, np.inf)
    dist[0] = 0.0
    for _ in range(n):
        cand = (wn + dist[None, :]).min(axis=1)
        dist = np.minimum(dist, cand)
    got = np.asarray(d, np.float64).ravel()
    finite = dist < 1e29
    assert_allclose(got[finite], dist[finite], rtol=1e-5)
    assert (got[~finite] >= 1e29).all()


def test_sssp_changed_zero_on_fixpoint_input():
    n = 16
    w = jnp.full((n, n), float(INF), jnp.float32)
    d = jnp.asarray(np.linspace(0, 10, n, dtype=np.float32).reshape(n, 1))
    _, changed = sssp_local_phase(w, d, steps=8, block=8)
    assert int(changed) == 0
