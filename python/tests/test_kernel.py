"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (multiples of the block), seeds and value ranges;
assert_allclose against ref.py. This is the CORE correctness signal for
the compute layer.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.minplus import (
    INF,
    blocked_minplus_matvec,
    sssp_step,
)
from compile.kernels.pagerank_block import blocked_matvec, pagerank_step

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def rng_mat(seed, n, lo=-1.0, hi=1.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.uniform(lo, hi, size=(n, n)).astype(np.float32))


def rng_vec(seed, n, lo=-1.0, hi=1.0):
    r = np.random.default_rng(seed + 777)
    return jnp.asarray(r.uniform(lo, hi, size=(n, 1)).astype(np.float32))


# ---------------------------------------------------------------- matvec


@given(
    nblocks=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([8, 16, 32, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_blocked_matvec_matches_ref(nblocks, block, seed):
    n = nblocks * block
    m, x = rng_mat(seed, n), rng_vec(seed, n)
    got = blocked_matvec(m, x, block=block)
    want = ref.matvec_ref(m, x)
    assert got.shape == (n, 1)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_blocked_matvec_value_ranges(seed, scale):
    n = 64
    m = rng_mat(seed, n, -scale, scale)
    x = rng_vec(seed, n, -scale, scale)
    got = blocked_matvec(m, x, block=16)
    assert_allclose(
        np.asarray(got), np.asarray(ref.matvec_ref(m, x)), rtol=1e-4, atol=1e-4 * scale * scale
    )


def test_blocked_matvec_identity():
    n = 32
    m = jnp.eye(n, dtype=jnp.float32)
    x = rng_vec(3, n)
    assert_allclose(np.asarray(blocked_matvec(m, x, block=8)), np.asarray(x), rtol=1e-6)


def test_blocked_matvec_zero_matrix():
    n = 16
    got = blocked_matvec(jnp.zeros((n, n), jnp.float32), rng_vec(0, n), block=8)
    assert_allclose(np.asarray(got), np.zeros((n, 1), np.float32))


def test_blocked_matvec_rejects_bad_shapes():
    with pytest.raises(ValueError):
        blocked_matvec(jnp.zeros((8, 16), jnp.float32), jnp.zeros((16, 1), jnp.float32), block=8)
    with pytest.raises(ValueError):
        blocked_matvec(jnp.zeros((12, 12), jnp.float32), jnp.zeros((12, 1), jnp.float32), block=8)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_pagerank_step_matches_ref(seed):
    n = 64
    m, r, d = rng_mat(seed, n, 0.0, 1.0), rng_vec(seed, n, 0.0, 1.0), rng_vec(seed + 1, n)
    got_r, got_d = pagerank_step(m, r, d, block=16)
    want_r, want_d = ref.pagerank_step_ref(m, r, d)
    assert_allclose(np.asarray(got_r), np.asarray(want_r), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- minplus


def sparse_weights(seed, n, density=0.2):
    r = np.random.default_rng(seed)
    w = np.full((n, n), float(INF), np.float32)
    mask = r.uniform(size=(n, n)) < density
    w[mask] = r.uniform(0.1, 10.0, size=mask.sum()).astype(np.float32)
    return jnp.asarray(w)


@given(
    nblocks=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([8, 16, 32, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_blocked_minplus_matches_ref(nblocks, block, seed):
    n = nblocks * block
    w = sparse_weights(seed, n)
    d = rng_vec(seed, n, 0.0, 100.0)
    got = blocked_minplus_matvec(w, d, block=block)
    want = ref.minplus_matvec_ref(w, d)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_sssp_step_matches_ref(seed):
    n = 64
    w = sparse_weights(seed, n)
    d = rng_vec(seed, n, 0.0, 100.0)
    got = sssp_step(w, d, block=16)
    want = ref.sssp_step_ref(w, d)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_minplus_all_inf_is_noop_through_step():
    n = 16
    w = jnp.full((n, n), float(INF), jnp.float32)
    d = rng_vec(5, n, 0.0, 10.0)
    got = sssp_step(w, d, block=8)
    assert_allclose(np.asarray(got), np.asarray(d))


def test_minplus_never_increases_distance():
    n = 32
    w = sparse_weights(11, n, density=0.5)
    d = rng_vec(11, n, 0.0, 50.0)
    got = np.asarray(sssp_step(w, d, block=8))
    assert (got <= np.asarray(d) + 1e-6).all()


def test_minplus_inf_padding_is_stable():
    # Padded region (rows/cols n..N) must stay at INF and not corrupt
    # the live region — exactly what runtime/accel.rs relies on.
    n, live = 32, 20
    w = np.full((n, n), float(INF), np.float32)
    rng = np.random.default_rng(0)
    w[:live, :live] = np.where(
        rng.uniform(size=(live, live)) < 0.3,
        rng.uniform(0.1, 5.0, size=(live, live)),
        float(INF),
    ).astype(np.float32)
    d = np.full((n, 1), float(INF), np.float32)
    d[0, 0] = 0.0
    w_j, d_j = jnp.asarray(w), jnp.asarray(d)
    got = np.asarray(sssp_step(w_j, d_j, block=8))
    want = np.asarray(ref.sssp_step_ref(w_j, d_j))
    assert_allclose(got, want, rtol=1e-6)
    assert (got[live:] >= float(INF) / 2).all()
